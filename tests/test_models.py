"""Per-architecture smoke tests (reduced configs, task spec): one forward
+ one train step on CPU asserting output shapes and finite values; plus
prefill+decode == full-forward consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.launch import steps as st
from repro.models import api
from repro.models.layers import is_axes_leaf
from repro.train.optimizer import OptConfig, init_opt_state

ARCHS = list(list_archs())


def _smoke_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model))
            .astype(np.float32) * 0.02)
    if cfg.family == "vlm":
        P = cfg.vision_patches
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)).astype(np.float32) * 0.02)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S + P, dtype=jnp.int32)[None, None], (3, B, S + P))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    logits = api.forward(params, cfg, batch)
    S_out = 16 + (cfg.vision_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    oc = OptConfig(lr=1e-3)
    opt = init_opt_state(params, oc)
    step = st.make_train_step(cfg, oc)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     params, params2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_axes_tree_matches_params(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    ax = api.axes(cfg)
    s1 = jax.tree.structure(params)
    s2 = jax.tree.structure(ax, is_leaf=is_axes_leaf)
    assert s1 == s2
    for a, p in zip(jax.tree.leaves(ax, is_leaf=is_axes_leaf),
                    jax.tree.leaves(params)):
        assert len(a) == p.ndim, (arch, a, p.shape)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).replace(
        remat=False, dtype=jnp.float32, use_lut_softmax=False,
        # GShard capacity routing is grouping-dependent when tokens drop;
        # a generous capacity factor makes prefill/decode == forward exact
        capacity_factor=8.0)
    params = api.init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    rng = np.random.default_rng(3)
    batch = _smoke_batch(cfg, B, S, rng)
    full = api.forward(params, cfg, batch)

    P = cfg.vision_patches if cfg.family == "vlm" else 0
    cache = api.init_cache(cfg, B, P + S)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : S - 1]
    if cfg.family == "vlm":
        pre["positions"] = batch["positions"][:, :, : P + S - 1]
    lg_pre, cache = api.prefill_step(params, cfg, pre, cache)
    lg_dec, _ = api.serve_step(params, cfg, batch["tokens"][:, S - 1 : S],
                               cache, jnp.asarray(P + S - 1, jnp.int32))
    np.testing.assert_allclose(lg_pre, full[:, -2], rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(lg_dec, full[:, -1], rtol=1e-4, atol=1e-3)


def test_cache_axes_structure_matches_cache():
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        cache = jax.eval_shape(lambda c=cfg: api.init_cache(c, 2, 8))
        ax = api.cache_axes(cfg)
        s1 = jax.tree.structure(cache)
        s2 = jax.tree.structure(ax, is_leaf=is_axes_leaf)
        assert s1 == s2, arch
        for a, c in zip(jax.tree.leaves(ax, is_leaf=is_axes_leaf),
                        jax.tree.leaves(cache)):
            assert len(a) == len(c.shape), (arch, a, c.shape)
