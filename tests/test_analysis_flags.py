"""Roofline-analysis math, optimization flags, HLO collective parser,
pipeline bubble model, dataflow comparison helpers."""
import os

import numpy as np
import pytest

from repro.core.dataflow import Dataflow, TileConfig, reduction_vs
from repro.launch.analysis import model_flops, model_params, roofline_terms
from repro.launch.dryrun import parse_collectives
from repro.parallel.flags import opt
from repro.parallel.pipeline import bubble_fraction
from repro.configs import SHAPES, get_config


def test_flags_defaults_and_baseline(monkeypatch):
    monkeypatch.delenv("REPRO_BASELINE", raising=False)
    monkeypatch.delenv("REPRO_OPT_FLASH", raising=False)
    assert opt("FLASH") is True
    monkeypatch.setenv("REPRO_OPT_FLASH", "0")
    assert opt("FLASH") is False
    monkeypatch.setenv("REPRO_OPT_FLASH", "1")
    assert opt("FLASH") is True
    monkeypatch.setenv("REPRO_BASELINE", "1")
    assert opt("FLASH") is False          # baseline overrides everything


def test_parse_collectives_ring_model():
    hlo = """
  %ar = bf16[1024,512]{1,0} all-reduce(bf16[1024,512] %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(f32[4,128] %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %z), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo, 256)
    B_ar = 1024 * 512 * 2
    assert out["all-reduce"]["count"] == 1
    np.testing.assert_allclose(out["all-reduce"]["wire_bytes"],
                               2 * B_ar * 15 / 16)
    B_ag = 64 * 128 * 4
    np.testing.assert_allclose(out["all-gather"]["wire_bytes"],
                               B_ag * 3 / 4)
    assert out["collective-permute"]["wire_bytes"] == 8 * 8 * 2


def test_model_params_moe_active_fraction():
    cfg = get_config("arctic-480b", smoke=True)
    p = model_params(cfg)
    assert p["active"] < p["total"]
    # experts are top-2 of 8 in the smoke config: active expert share = 1/4
    assert p["active"] / p["total"] > 0.2


def test_model_flops_kinds():
    cfg = get_config("llama2-7b", smoke=True)
    t = model_flops(cfg, SHAPES["train_4k"], 256)
    pfl = model_flops(cfg, SHAPES["prefill_32k"], 256)
    d = model_flops(cfg, SHAPES["decode_32k"], 256)
    assert t == 3 * model_params(cfg)["active"] * 2 * 256 * 4096
    assert d == 2 * model_params(cfg)["active"] * 128
    assert pfl > d


def test_roofline_terms_dominant():
    rec = {
        "status": "ok", "arch": "llama2-7b", "shape": "decode_32k",
        "n_devices": 256,
        "analysis": {"flops_per_device": 197e12, "bytes_per_device": 819e9,
                     "wire_bytes_per_device": 100e9},
        "memory_analysis": {},
    }
    t = roofline_terms(rec)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 2.0) < 1e-9
    assert t["dominant"] == "collective"


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(15, 4) == pytest.approx(3 / 18)
    assert bubble_fraction(100, 1) == 0.0


def test_reduction_vs_matches_paper_direction():
    tc = TileConfig(M=1024, N=4096, K=4096, m=128, n=256, k=256)
    r = reduction_vs(Dataflow.WS_OCS, Dataflow.WS, tc)
    assert 0.3 < r < 0.7      # the Fig-8a regime
