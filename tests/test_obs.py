"""Serving telemetry subsystem (DESIGN.md §15): span tracer + Chrome
trace export, metrics registry + Prometheus text export, the unified
dispatch census, pool-stat folding, and the modeled-vs-measured drift
report — including span-stream well-formedness under the two lifecycle
shapes that historically break tracers: preempted-then-replayed
requests and disaggregated prefill→decode handoffs."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.models import api
from repro.serve.batching import Request
from repro.serve.engine import Engine, quantize_params
from repro.serve.paged import Scheduler
from repro.serve.paged.disagg import DisaggScheduler


# ---------------------------------------------------------------------------
# tracer / exporter units
# ---------------------------------------------------------------------------

def test_tracer_span_nesting_and_chrome_export(tmp_path):
    tr = obs.Tracer(enabled=True)
    h = tr.begin("request", tid=obs.request_tid(0), rid=0)
    with tr.span("prefill_chunk", tid=obs.request_tid(0), pos=0):
        pass
    tr.event("first_token", tid=obs.request_tid(0))
    with tr.span("decode_tick", n_active=1):      # scheduler lane
        pass
    assert tr.open_count == 1
    tr.end(h, outcome="finish")
    assert tr.open_count == 0

    out = tmp_path / "trace.json"
    doc = tr.export_chrome(out)
    # the on-disk artifact is the same JSON document
    assert json.loads(out.read_text()) == doc
    counts = obs.validate_chrome_trace(doc)
    assert counts == {"spans": 3, "events": 1, "lanes": 2}
    lives = obs.request_lifecycles(doc)
    assert len(lives[0]["roots"]) == 1
    assert [c["name"] for c in lives[0]["children"]] == ["prefill_chunk"]
    # lane metadata rows name the process and both lanes
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta
             if e["name"] == "thread_name"}
    assert names == {"scheduler", "request 0"}


def test_tracer_rejects_malformed_streams():
    tr = obs.Tracer(enabled=True)
    # partial overlap in one lane (not proper nesting) must be rejected
    tr._record("a", 1, 0.0, 2.0, None)
    tr._record("b", 1, 1.0, 3.0, None)
    with pytest.raises(ValueError, match="overlap"):
        obs.validate_chrome_trace(tr.export_chrome())
    # a request lane without a completed root is an orphan stream
    tr2 = obs.Tracer(enabled=True)
    with tr2.span("prefill_chunk", tid=obs.request_tid(3)):
        pass
    with pytest.raises(ValueError, match="no completed root"):
        obs.request_lifecycles(tr2.export_chrome())


def test_tracer_disabled_is_noop():
    tr = obs.Tracer(enabled=False)
    # zero-cost: the disabled span is one shared nullcontext, no record
    assert tr.span("x") is tr.span("y", tid=5, foo=1)
    tr.event("e")
    h = tr.begin("request", tid=1)
    assert h == 0
    tr.end(h)
    assert tr.spans() == [] and tr.events() == [] and tr.open_count == 0


def test_metrics_registry_and_prometheus_roundtrip(tmp_path):
    m = obs.Metrics(enabled=True)
    m.counter("tokens_emitted_total").inc(7)
    m.gauge("pool_num_free", labels={"pool": "decode"}).set(3)
    h = m.histogram("ttft_seconds")
    for v in (0.01, 0.03):
        h.observe(v)
    assert m.value("tokens_emitted_total") == 7
    assert h.count == 2 and h.mean == pytest.approx(0.02)

    out = tmp_path / "metrics.prom"
    text = m.export_prometheus(out)
    assert out.read_text() == text
    samples = obs.parse_prometheus(text)
    assert samples["repro_tokens_emitted_total"] == 7
    assert samples['repro_pool_num_free{pool="decode"}'] == 3
    assert samples["repro_ttft_seconds_count"] == 2
    assert samples["repro_ttft_seconds_sum"] == pytest.approx(0.04)
    # cumulative buckets: every le-bound ≥ 0.03 saw both observations
    assert samples['repro_ttft_seconds_bucket{le="+Inf"}'] == 2
    assert "ttft" in m.summary()
    m.reset()
    assert m.get("tokens_emitted_total") is None

    off = obs.Metrics(enabled=False)
    # the disabled registry hands out one shared no-op instrument
    assert off.counter("a") is off.histogram("b")
    off.counter("a").inc()
    assert off.value("a") == 0.0 and off.export_prometheus() == ""


# ---------------------------------------------------------------------------
# dispatch census unification (satellite: engine eqn counts → obs)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=256)


def test_dispatch_census_unifies_eqn_counts():
    cfg = _tiny_cfg()
    params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
    eng = Engine(cfg, params, max_len=64)
    # the legacy wrappers and the unified census walk the SAME cached
    # jaxpr, so the numbers must agree exactly
    c = eng.dispatch_census("decode")
    assert c["total"] == eng.decode_eqn_count()
    assert c["pallas_call"] == eng.decode_eqn_count(primitive="pallas_call")
    p = eng.dispatch_census("prefill", chunk=8, block_size=8)
    assert p["total"] == eng.prefill_eqn_count(chunk=8, block_size=8)
    # verify is structurally prefill at chunk = k+1 (DESIGN.md §12)
    assert eng.dispatch_census("verify", k=7, block_size=8) == p
    with pytest.raises(ValueError):
        eng.dispatch_census("warmup")

    # the standalone census works on arbitrary callables, and folding
    # lands per-primitive gauges in the registry
    cen = obs.dispatch_census(lambda a, b: a @ b + 1.0,
                              jnp.ones((2, 3)), jnp.ones((3, 2)))
    assert cen["dot_general"] == 1 and cen["total"] >= 2
    m = obs.Metrics(enabled=True)
    obs.fold_census(m, cen, phase="decode")
    assert m.value("kernel_dispatches",
                   {"phase": "decode", "primitive": "dot_general"}) == 1


# ---------------------------------------------------------------------------
# lifecycle well-formedness through the scheduler
# ---------------------------------------------------------------------------

def _instrumented_run(cfg, params, prompts, news, **kw):
    trace = obs.Tracer(enabled=True)
    metrics = obs.Metrics(enabled=True)
    sch = Scheduler(cfg, params, trace=trace, metrics=metrics, **kw)
    for i, (p, n) in enumerate(zip(prompts, news)):
        sch.submit(Request(rid=i, prompt=p, max_new=n))
    done = sch.run()
    return done, sch, trace, metrics


@pytest.mark.parametrize("arch,extra", [
    ("llama2-7b", dict(num_layers=2)),
    ("dbrx-132b", dict(capacity_factor=8.0)),     # MoE
    ("qwen2-vl-2b", dict()),                      # VLM
])
def test_scheduler_trace_and_metrics_reconcile(rng, arch, extra):
    """Acceptance: a paged run on every model family exports a valid
    Chrome trace (one complete admit→finish lifecycle per request) and
    Prometheus metrics whose token counters EXACTLY match the
    scheduler's returned output."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32, **extra)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9)]
    news = [5, 4, 6]
    done, sch, trace, metrics = _instrumented_run(
        cfg, params, prompts, news, slots=2, max_len=64, block_size=8,
        chunk=8)

    assert trace.open_count == 0
    doc = trace.export_chrome()
    obs.validate_chrome_trace(doc)                # monotone + nested
    lives = obs.request_lifecycles(doc)           # no orphans
    assert set(lives) == set(done)
    for rid, rec in lives.items():
        assert len(rec["roots"]) == 1             # no preemption here
        assert rec["roots"][0]["args"]["outcome"] == "finish"
        ev = [e["name"] for e in rec["events"]]
        assert ev.count("admit") == 1 and ev.count("finish") == 1
        assert ev.count("first_token") == 1

    toks = sum(len(v) for v in done.values())
    assert metrics.value("tokens_emitted_total") == toks == sum(news)
    assert metrics.value("requests_admitted_total") == len(prompts)
    assert metrics.value("requests_finished_total") == len(prompts)
    assert metrics.get("ttft_seconds").count == len(prompts)
    assert metrics.value("decode_ticks_total") == \
        metrics.get("decode_tick_seconds").count
    # run() folds the pool gauges; the export round-trips them
    samples = obs.parse_prometheus(metrics.export_prometheus())
    assert samples["repro_tokens_emitted_total"] == toks
    assert samples["repro_pool_peak_in_use"] == sch.pool.peak_in_use


def test_preempted_then_replayed_request_spans(rng):
    """Preemption closes the victim's root (outcome=preempt) and replay
    opens a NEW root in the same lane — the exported stream must stay
    well-formed (no orphans, monotone, nested) with TTFT counted only
    for first attempts and token counts still exact."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(0), cfg)
    # 2 streams × (20-token prompt + 16 new) need 5 blocks each; the
    # pool has 7 usable — decode growth must preempt the younger stream
    prompts = [rng.integers(1, cfg.vocab_size, size=20).tolist()
               for _ in range(2)]
    news = [16, 16]
    done, sch, trace, metrics = _instrumented_run(
        cfg, params, prompts, news, slots=2, max_len=48, block_size=8,
        num_blocks=8, chunk=8)

    n_pre = int(metrics.value("requests_preempted_total"))
    assert n_pre >= 1, "setup no longer forces preemption"
    assert metrics.value("requests_replayed_total") == n_pre
    assert trace.open_count == 0
    doc = trace.export_chrome()
    obs.validate_chrome_trace(doc)
    lives = obs.request_lifecycles(doc)
    roots = [r for rec in lives.values() for r in rec["roots"]]
    assert len(roots) == len(prompts) + n_pre
    outcomes = [r["args"]["outcome"] for r in roots]
    assert outcomes.count("preempt") == n_pre
    assert outcomes.count("finish") == len(prompts)
    # the replayed admission carries its replay count on the root
    assert max(r["args"]["replays"] for r in roots) == n_pre
    # TTFT observed once per request (first attempt only, never the
    # replayed re-prefill), and tokens stay exact through the replay
    assert metrics.get("ttft_seconds").count == len(prompts)
    assert metrics.value("tokens_emitted_total") == \
        sum(len(v) for v in done.values()) == sum(news)


def test_disagg_handoff_spans_one_lane(rng):
    """DisaggScheduler shares one tracer/metrics pair across both pools:
    a request's lane holds the prefill root (outcome=handoff) and the
    decode root (adopted) back to back — no orphans, exact tokens, and
    per-pool labeled gauges from both pools' folds."""
    cfg = _tiny_cfg()
    params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9)]
    news = [5, 4, 6]
    trace = obs.Tracer(enabled=True)
    metrics = obs.Metrics(enabled=True)
    sch = DisaggScheduler(cfg, params, slots=2, max_len=64, block_size=8,
                          chunk=8, trace=trace, metrics=metrics)
    for i, (p, n) in enumerate(zip(prompts, news)):
        sch.submit(Request(rid=i, prompt=p, max_new=n))
    done = sch.run()

    assert trace.open_count == 0
    doc = trace.export_chrome()
    obs.validate_chrome_trace(doc)
    lives = obs.request_lifecycles(doc)
    assert set(lives) == set(done) == set(range(len(prompts)))
    for rec in lives.values():
        outcomes = [r["args"]["outcome"] for r in rec["roots"]]
        assert outcomes == ["handoff", "finish"]
        ev = [e["name"] for e in rec["events"]]
        assert "handoff" in ev and "adopt" in ev
    assert metrics.value("handoffs_total") == len(prompts)
    assert metrics.value("adoptions_total") == len(prompts)
    assert metrics.value("handoff_bytes_total") == sch.handoff_bytes
    assert metrics.value("tokens_emitted_total") == \
        sum(len(v) for v in done.values()) == sum(news)
    # both pools folded their gauges under distinct labels
    samples = obs.parse_prometheus(metrics.export_prometheus())
    for pool in ("prefill", "decode"):
        assert f'repro_pool_num_free{{pool="{pool}"}}' in samples


# ---------------------------------------------------------------------------
# drift report
# ---------------------------------------------------------------------------

def test_drift_report_calibration_and_rows():
    m = obs.Metrics(enabled=True)
    # synthetic run: 4-active decode ticks + 8-token prefill chunks
    for _ in range(5):
        m.histogram("tick_active").observe(4)
        m.histogram("decode_tick_seconds").observe(0.02)
        m.histogram("prefill_chunk_seconds").observe(0.012)
    rows = obs.drift_report(m, chunk=8, ctx=128)
    by = {r["name"].split()[0]: r for r in rows}
    assert set(by) == {"decode", "prefill"}
    dec, pre = by["decode"], by["prefill"]
    assert dec["measured"] == pytest.approx(0.005)
    assert pre["measured"] == pytest.approx(0.0015)
    # κ calibration makes two-row drift symmetric in log space: the
    # residuals multiply out to exactly 1
    assert dec["kappa"] == pytest.approx(pre["kappa"])
    assert (1 + dec["drift_pct"] / 100) * (1 + pre["drift_pct"] / 100) \
        == pytest.approx(1.0)
    txt = obs.format_report(rows)
    assert "kappa" in txt and "drift=" in txt
    assert obs.format_report([]).startswith("(no drift rows")


def test_drift_report_sparse_factor_row():
    cfg = _tiny_cfg().replace(sparsity="2:4")
    params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
    m = obs.Metrics(enabled=True)
    rows = obs.drift_report(m, params=params)
    (row,) = [r for r in rows if r["name"].startswith("sparse")]
    # 2:4 w4 bitmask storage: 0.5 value bytes + metadata ≈ the modeled
    # 0.75 weight-stream factor, directly comparable (dimensionless)
    assert row["modeled"] == pytest.approx(0.75)
    assert abs(row["drift_pct"]) < 10.0
    assert row["kappa"] is None
    # dense params → no sparse leaves → the row disappears
    dense = quantize_params(api.init(jax.random.PRNGKey(0), _tiny_cfg()),
                            _tiny_cfg())
    assert obs.drift_report(m, params=dense) == []


# ---------------------------------------------------------------------------
# env-gated defaults (REPRO_TRACE / REPRO_METRICS, default off)
# ---------------------------------------------------------------------------

def test_default_telemetry_env_gated(monkeypatch):
    import repro.obs as o
    monkeypatch.setattr(o, "_tracer", None)
    monkeypatch.setattr(o, "_metrics", None)
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    monkeypatch.delenv("REPRO_METRICS", raising=False)
    assert not o.default_tracer().enabled
    assert not o.default_metrics().enabled
    monkeypatch.setattr(o, "_tracer", None)
    monkeypatch.setattr(o, "_metrics", None)
    monkeypatch.setenv("REPRO_TRACE", "1")
    monkeypatch.setenv("REPRO_METRICS", "1")
    assert o.default_tracer().enabled
    assert o.default_metrics().enabled
    # singletons: repeat calls hand back the same instance
    assert o.default_tracer() is o.default_tracer()
    monkeypatch.setattr(o, "_tracer", None)
    monkeypatch.setattr(o, "_metrics", None)
