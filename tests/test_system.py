"""End-to-end behaviour: the full loop (data → sharded train → checkpoint
→ quantize → serve) on a tiny model, exercising the paper's technique
stack (WS-OCS quantized matmuls, LUT group softmax, fused norms) in one
pass; plus dry-run cell smoke via subprocess-free smoke configs."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serve.engine import Engine, ServeConfig, quantize_params
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def test_train_quantize_serve_roundtrip(tmp_path):
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    mesh = make_host_mesh()
    dc = DataConfig(seed=1, batch_size=4, seq_len=32,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=40, ckpt_every=40,
                     ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(cfg, mesh, dc, tc, OptConfig(lr=3e-3, warmup_steps=5))
    losses = []
    tr.run(on_metrics=lambda s, m: losses.append(m["loss"]))
    # training moves (the strict monotone-trend check is
    # test_train_serve.test_loss_decreases over 150 steps; 40 steps is
    # inside the noise band of the synthetic stream)
    assert min(losses) < losses[0]
    assert np.isfinite(losses).all()

    # deploy exactly like the paper: W4A8 + LUT softmax + fusion
    scfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True)
    qparams = quantize_params(jax.device_get(tr.params), scfg)
    eng = Engine(scfg, qparams, max_len=48)
    prompt = np.array([[1, 5, 9, 4]], np.int32)
    out = eng.generate(prompt, ServeConfig(max_new_tokens=8))
    assert out.shape == (1, 12)
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)


def test_dryrun_smoke_cell_subprocess(tmp_path):
    """The dry-run entrypoint works end-to-end (smoke config, real 512
    placeholder devices, real lower+compile+analysis)."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "llama2-7b", "--shape", "decode_32k",
           "--mesh", "multi", "--smoke", "--no-analysis",
           "--out", str(tmp_path)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert (tmp_path / "llama2-7b_decode_32k_multi.json").exists()
