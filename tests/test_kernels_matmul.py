"""WS-OCS / RCW matmul kernels vs the pure-jnp oracle, across shapes,
dtypes, bit-widths, block sizes, and the rcw on/off ablation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig, quantize_weight, quantize_int8
from repro.kernels import ref
from repro.kernels.ws_ocs_matmul import rcw_matmul, ws_ocs_matmul

SHAPES = [(32, 64, 32), (64, 256, 128), (128, 128, 256), (16, 512, 64)]


def _qw(rng, n, k, mode="w4a8", group=64):
    w = rng.standard_normal((n, k)).astype(np.float32)
    return quantize_weight(jnp.asarray(w), QuantConfig(mode, group))


@pytest.mark.parametrize("M,N,K", SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_panel_kernel_matches_ref(rng, M, N, K, bits):
    mode = "w4a8" if bits == 4 else "w8a8"
    qw = _qw(rng, N, K, mode)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    want = ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=bits)
    got = ws_ocs_matmul(x, qw.data, qw.scale, bits=bits, bm=min(32, M),
                        bk=min(64, K), interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,N,K", SHAPES[:3])
@pytest.mark.parametrize("rcw", [True, False])
def test_rcw_kernel_matches_ref(rng, M, N, K, rcw):
    qw = _qw(rng, N, K)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    want = ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4)
    got = rcw_matmul(x, qw.data, qw.scale, bits=4, bm=min(32, M),
                     bk=min(32, K), rcw=rcw, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_int8_activations_with_row_scale(rng):
    M, N, K = 32, 128, 64
    qw = _qw(rng, N, K)
    xf = rng.standard_normal((M, N)).astype(np.float32)
    xq, xs = quantize_int8(jnp.asarray(xf), axis=-1)
    want = ref.ws_ocs_matmul_ref(xq, qw.data, qw.scale, bits=4, x_scale=xs)
    got = ws_ocs_matmul(xq, qw.data, qw.scale, bits=4, x_scale=xs,
                        bm=16, bk=32, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # W4A8 path approximates the fp32 matmul within quantization error
    exact = xf @ np.asarray(ref.dequant_weight_ref(qw.data, qw.scale, 4))
    rel = np.abs(np.asarray(got) - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.02


def test_weight_panel_stationarity_traffic(rng):
    """Structural WS-OCS property: the weight block index map ignores the
    inner (m) grid index → each panel is fetched exactly once (NK weight
    traffic, Table I)."""
    from repro.kernels import ws_ocs_matmul as mod
    # the panel index_map is lambda k, m: (0, k): constant in m
    got = [mod.ws_ocs_matmul.__wrapped__ if hasattr(mod.ws_ocs_matmul, "__wrapped__") else None]
    idx = (lambda k, m: (0, k))
    assert idx(3, 0) == idx(3, 99)  # stationary across the m sweep


@pytest.mark.parametrize("fn", ["ws", "fused", "rcw"])
def test_untileable_error_reports_shapes(rng, fn):
    """Indivisible grid shapes must raise a ValueError naming the
    offending operand shapes and the chosen vs requested block sizes
    (PR 7 attention-kernel error style), not a bare assert."""
    from repro.kernels.ws_ocs_matmul import fused_matmul
    qw = _qw(rng, 32, 48)
    x = jnp.asarray(rng.standard_normal((10, 32)).astype(np.float32))
    call = {
        "ws": lambda: ws_ocs_matmul(x, qw.data, qw.scale, bits=4,
                                    bm=4, bk=48, interpret=True),
        "fused": lambda: fused_matmul(x, qw.data, qw.scale, bits=4,
                                      bm=4, bk=48, interpret=True),
        "rcw": lambda: rcw_matmul(x, qw.data, qw.scale, bits=4,
                                  bm=4, bk=48, interpret=True),
    }[fn]
    with pytest.raises(ValueError) as ei:
        call()
    msg = str(ei.value)
    assert "(10, 32)" in msg and "bm=4" in msg and "M % bm == 2" in msg, msg


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_input_dtypes(rng, dtype):
    M, N, K = 32, 128, 64
    qw = _qw(rng, N, K)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32)).astype(dtype)
    want = ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4)
    got = ws_ocs_matmul(x, qw.data, qw.scale, bits=4, bm=16, bk=32,
                        interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
