"""Tier-1 exercise of the benchmark perf rows: the smoke gate must run
the PR 3 fused rows end-to-end and write BENCH_pr3.json."""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_bench_smoke_fast_rows(tmp_path):
    out = tmp_path / "BENCH_pr3.json"
    env = dict(os.environ, PYTHONPATH="src", REPRO_BENCH_JSON=str(out))
    proc = subprocess.run(
        [sys.executable, "benchmarks/smoke.py", "--fast"], cwd=ROOT,
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-1000:]
    data = json.loads(out.read_text())
    names = {r["name"] for r in data["rows"]}
    assert {"kernel_fused_norm_glu_1024x2048",
            "kernel_fused_attn_decode_512",
            "decode_dispatch_unfused", "decode_dispatch_fused",
            "decode_dispatch_reduction"} <= names, names
    # acceptance: fused decode dispatches strictly fewer jaxpr eqns
    by = {r["name"]: r["derived"] for r in data["rows"]}
    eq = {t: int(by[f"decode_dispatch_{t}"].split(";")[0].split("=")[1])
          for t in ("unfused", "fused")}
    assert eq["fused"] < eq["unfused"], eq
