"""Tier-1 exercise of the benchmark perf rows: the smoke gate must run
the PR 3 fused rows and the PR 5 paged-serving rows end-to-end and
write BENCH_pr3.json / BENCH_pr5.json."""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_bench_smoke_fast_rows(tmp_path):
    out = tmp_path / "BENCH_pr3.json"
    out5 = tmp_path / "BENCH_pr5.json"
    env = dict(os.environ, PYTHONPATH="src", REPRO_BENCH_JSON=str(out),
               REPRO_BENCH_PR5_JSON=str(out5))
    proc = subprocess.run(
        [sys.executable, "benchmarks/smoke.py", "--fast"], cwd=ROOT,
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-1000:]
    data = json.loads(out.read_text())
    names = {r["name"] for r in data["rows"]}
    assert {"kernel_fused_norm_glu_1024x2048",
            "kernel_fused_attn_decode_512",
            "decode_dispatch_unfused", "decode_dispatch_fused",
            "decode_dispatch_reduction"} <= names, names
    # acceptance: fused decode dispatches strictly fewer jaxpr eqns
    by = {r["name"]: r["derived"] for r in data["rows"]}
    eq = {t: int(by[f"decode_dispatch_{t}"].split(";")[0].split("=")[1])
          for t in ("unfused", "fused")}
    assert eq["fused"] < eq["unfused"], eq
    # PR 5 rows: paged serving must reference measurably fewer KV blocks
    # than the dense slots × max_len allocation at both slot counts
    rows5 = {r["name"]: dict(kv.split("=") for kv in r["derived"].split(";"))
             for r in json.loads(out5.read_text())["rows"]}
    for slots in (4, 16):
        got = rows5[f"paged_paged_tok_s_slots{slots}"]
        assert int(got["peak_kv_blocks"]) < int(got["dense_equiv_blocks"]), got
