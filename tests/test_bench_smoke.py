"""Tier-1 exercise of the benchmark perf rows: the smoke gate must run
the PR 3 fused rows, the PR 5 paged-serving rows, the PR 6
chunked-prefill kernelization rows, the PR 9 structured-sparsity rows,
and the PR 10 serving-telemetry rows end-to-end and write
BENCH_pr3.json / BENCH_pr5.json / BENCH_pr6.json / BENCH_pr9.json /
BENCH_pr10.json."""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _kv(derived):
    return dict(kv.split("=", 1) for kv in derived.split(";"))


def test_bench_smoke_fast_rows(tmp_path):
    out = tmp_path / "BENCH_pr3.json"
    out5 = tmp_path / "BENCH_pr5.json"
    out6 = tmp_path / "BENCH_pr6.json"
    out9 = tmp_path / "BENCH_pr9.json"
    out10 = tmp_path / "BENCH_pr10.json"
    env = dict(os.environ, PYTHONPATH="src", REPRO_BENCH_JSON=str(out),
               REPRO_BENCH_PR5_JSON=str(out5),
               REPRO_BENCH_PR6_JSON=str(out6),
               REPRO_BENCH_PR9_JSON=str(out9),
               REPRO_BENCH_PR10_JSON=str(out10))
    proc = subprocess.run(
        [sys.executable, "benchmarks/smoke.py", "--fast"], cwd=ROOT,
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-1000:]
    data = json.loads(out.read_text())
    names = {r["name"] for r in data["rows"]}
    assert {"kernel_fused_norm_glu_1024x2048",
            "kernel_fused_attn_decode_512",
            "decode_dispatch_unfused", "decode_dispatch_fused",
            "decode_dispatch_reduction"} <= names, names
    # acceptance: fused decode dispatches strictly fewer jaxpr eqns
    by = {r["name"]: r["derived"] for r in data["rows"]}
    eq = {t: int(by[f"decode_dispatch_{t}"].split(";")[0].split("=")[1])
          for t in ("unfused", "fused")}
    assert eq["fused"] < eq["unfused"], eq
    # PR 5 rows: paged serving must reference measurably fewer KV blocks
    # than the dense slots × max_len allocation at both slot counts
    rows5 = {r["name"]: _kv(r["derived"])
             for r in json.loads(out5.read_text())["rows"]}
    for slots in (4, 16):
        got = rows5[f"paged_paged_tok_s_slots{slots}"]
        assert int(got["peak_kv_blocks"]) < int(got["dense_equiv_blocks"]), got
    # PR 6 rows: paged flash prefill must beat the PR 5 dense-oracle
    # chunk path on wall-clock at slots 4 and 16, op-level AND through
    # the scheduler, with token-identical outputs ...
    rows6 = {r["name"]: r for r in json.loads(out6.read_text())["rows"]}
    for slots in (4, 16):
        attn = _kv(rows6[f"prefill_attn_pagedflash_slots{slots}"]["derived"])
        assert float(attn["speedup_vs_oracle"].rstrip("x")) > 1.0, attn
        assert float(attn["maxerr"]) < 1e-5, attn
        sched = _kv(rows6[f"prefill_sched_flash_slots{slots}"]["derived"])
        assert float(sched["speedup_vs_oracle"].rstrip("x")) > 1.0, sched
        assert sched["tokens_identical"] == "True", sched
    # ... and with no dense KV materialization in the chunk hot loop:
    # the kernel arm keeps only the LM-head dot_general and drops the
    # oracle's densify gathers (the §11 residency invariant, counted)
    disp = {t: _kv(rows6[f"prefill_dispatch_{t}"]["derived"])
            for t in ("kernel", "oracle")}
    assert int(disp["kernel"]["dot_general"]) == 1, disp
    assert int(disp["kernel"]["pallas_calls"]) > 0, disp
    assert int(disp["oracle"]["dot_general"]) \
        - int(disp["kernel"]["dot_general"]) == 2, disp
    assert int(disp["oracle"]["gather"]) \
        - int(disp["kernel"]["gather"]) >= 2, disp
    # PR 9 rows: the row-skip sparse matmul must not lose to the
    # dense-masked baseline (≥1.5× in the full bench; ≥1.0× here — fast
    # smoke shares the machine with the rest of the suite), the sparse
    # int-accumulation kernel must match the dense-masked reference bit
    # for bit, and 2:4-sparse serving must stay token-identical
    rows9 = {r["name"]: _kv(r["derived"])
             for r in json.loads(out9.read_text())["rows"]}
    sp = rows9["sparse_matmul_speedup"]
    assert float(sp["speedup"].rstrip("x")) >= 1.0, sp
    assert rows9["sparse_bitexact_int"]["bit_exact"] == "True", rows9
    assert rows9["sparse_sched_sparse"]["tokens_identical"] == "True", rows9
    assert float(rows9["sparse_panel_bytes"]["reduction"]) == 0.25, rows9
    # PR 10 rows: the telemetry-on run must export a valid trace with
    # every request's lifecycle complete and no span left open, the
    # token counters must reconcile EXACTLY (metric == scheduler ==
    # Prometheus round-trip), and the drift report must produce the
    # calibrated decode/prefill rows. The ≤5% overhead budget is
    # asserted inside bench_obs itself (the row records the measurement;
    # a budget blow-out fails the subprocess above).
    rows10 = {r["name"]: _kv(r["derived"])
              for r in json.loads(out10.read_text())["rows"]}
    tv = rows10["obs_trace_valid"]
    assert tv["valid"] == "True" and tv["open_spans"] == "0", tv
    assert int(tv["lifecycles"]) > 0, tv
    assert rows10["obs_tokens_reconcile"]["tokens_match"] == "True", rows10
    assert "overhead_pct" in rows10["obs_sched_on"], rows10
    for phase in ("decode", "prefill"):
        assert f"obs_drift_{phase}" in rows10, rows10
        assert "drift_pct" in rows10[f"obs_drift_{phase}"], rows10
