"""Test fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; only launch/dryrun.py sets the 512-device
placeholder count (task spec)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
