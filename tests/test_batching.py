"""Continuous batching correctness: slot-shared decode with per-slot
positions must reproduce per-request greedy generation exactly (f32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference(cfg, params, prompt, max_new):
    eng = Engine(cfg, params, max_len=64)
    out = eng.generate(np.asarray([prompt], np.int32),
                       ServeConfig(max_new_tokens=max_new))
    return out[0, len(prompt):].tolist()


def test_matches_single_request_generation(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (8, 5, 13, 8, 3)]     # mixed lengths (pad buckets)
    news = [6, 9, 4, 7, 5]

    cb = ContinuousBatcher(cfg, params, slots=2, max_len=64)
    for i, (p, n) in enumerate(zip(prompts, news)):
        cb.submit(Request(rid=i, prompt=p, max_new=n))
    done = cb.run()

    assert sorted(done) == list(range(5))
    for i, (p, n) in enumerate(zip(prompts, news)):
        ref = _reference(cfg, params, p, n)
        assert done[i] == ref, (i, done[i], ref)


def test_eos_early_stop(setup):
    cfg, params = setup
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]
    ref = _reference(cfg, params, prompt, 8)
    eos = ref[2]        # force an early stop at the 3rd generated token
    cb = ContinuousBatcher(cfg, params, slots=1, max_len=64)
    cb.submit(Request(rid=0, prompt=prompt, max_new=8, eos=eos))
    done = cb.run()
    assert done[0] == ref[:3]


def test_eos_tracking_is_a_constructor_field(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=1, max_len=64)
    assert cb._req_eos == {}          # proper field, not a getattr default
    cb.submit(Request(rid=7, prompt=[1, 2, 3], max_new=2, eos=None))
    cb.run()
    assert 7 in cb._req_eos


def test_unified_admit_path_masks_bucket_junk(setup):
    """The single exact admission path (re-decode of the last prompt
    token) must never read cache contents past slot.pos: poison every
    cache position >= n with huge finite values right after _admit and
    the outputs must still match Engine.generate — for both a
    bucket-exact prompt (n == bucket) and a padded one (n < bucket)."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    for n in (8, 5):                  # bucket=8: exact and padded cases
        prompt = rng.integers(1, cfg.vocab_size, size=n).tolist()
        ref = _reference(cfg, params, prompt, 6)
        cb = ContinuousBatcher(cfg, params, slots=1, max_len=64)
        cb.submit(Request(rid=0, prompt=prompt, max_new=6))
        cb._admit()
        assert cb.slots[0].pos == n - 1          # one path for all n
        poison = jax.tree.map(
            lambda a: a.at[:, :, n:].set(jnp.asarray(1e6, a.dtype)),
            cb.cache)
        cb.cache = poison
        done = cb.run()
        assert done[0] == ref, (n, done[0], ref)


def test_more_requests_than_slots_throughput(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, size=8).tolist(),
                    max_new=4) for i in range(6)]
    cb = ContinuousBatcher(cfg, params, slots=3, max_len=32)
    for r in reqs:
        cb.submit(r)
    done = cb.run()
    assert len(done) == 6
    assert all(len(v) == 4 for v in done.values())
