"""Speculative & parallel decoding on COW block tables (DESIGN.md §12):
draft-provider acceptance mechanics, token-identity of the speculative
scheduler against the dense Engine for ANY draft (the §12 exactness
claim) across dense / MoE / VLM, beam forking that bit-matches
independently-seeded engine runs at sublinear peak KV, and the
speculation-adjusted perf-model rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.batching import Request
from repro.serve.engine import Engine, ServeConfig
from repro.serve.paged import Scheduler
from repro.serve.spec_decode import (ModelDraft, OracleDraft, SpecConfig,
                                     accept_length)
from repro.sim import perf_model as pm


def _engine_refs(cfg, params, prompts, news, max_len):
    eng = Engine(cfg, params, max_len=max_len)
    return {i: eng.generate(np.asarray([p], np.int32),
                            ServeConfig(max_new_tokens=n)
                            )[0, len(p):].tolist()
            for i, (p, n) in enumerate(zip(prompts, news))}


def _run_spec(cfg, params, prompts, news, spec, **kw):
    sch = Scheduler(cfg, params, spec=spec, **kw)
    for i, (p, n) in enumerate(zip(prompts, news)):
        sch.submit(Request(rid=i, prompt=p, max_new=n))
    return sch.run(), sch


def _dense_cfg():
    return get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                       num_layers=2)


# ---------------------------------------------------------------------------
# Acceptance mechanics
# ---------------------------------------------------------------------------

def test_accept_length():
    assert accept_length([1, 2, 3], [1, 2, 3, 4]) == 3
    assert accept_length([1, 2, 3], [1, 9, 3, 4]) == 1
    assert accept_length([5], [4, 4]) == 0
    assert accept_length([], [7]) == 0


def test_expected_tokens_per_pass():
    assert pm.expected_tokens_per_pass(4, 1.0) == 5.0
    assert pm.expected_tokens_per_pass(4, 0.0) == 1.0
    e = pm.expected_tokens_per_pass(4, 0.7)
    assert abs(e - (1 - 0.7 ** 5) / 0.3) < 1e-12 and 2.7 < e < 2.8
    # speculation-adjusted latency: beats plain amortization at high
    # acceptance, loses at low (the wasted-verify-lanes crossover)
    base = pm.amortized_decode_latency(4)
    assert pm.speculative_decode_latency(4, 4, 0.95) < base
    assert pm.speculative_decode_latency(4, 4, 0.05) > base


def test_oracle_draft_deterministic_and_dialable():
    seqs = {("r", 0): list(range(100, 140))}
    d = OracleDraft(seqs, accept_rate=0.5, seed=3, vocab_size=1000)
    a = d.draft(("r", 0), seqs[("r", 0)][:10], 6)
    b = d.draft(("r", 0), seqs[("r", 0)][:10], 6)
    assert a == b                                 # per-position determinism
    ref = seqs[("r", 0)][10:16]
    matches = sum(x == y for x, y in zip(a, ref))
    assert 0 < matches < 6                        # corrupted but not fully
    # past-end positions draft wrong-by-construction tokens
    tail = d.draft(("r", 0), seqs[("r", 0)], 3)
    assert all(t != 0 or True for t in tail) and len(tail) == 3
    # rate 1.0 → exact replay
    exact = OracleDraft(seqs, accept_rate=1.0).draft(
        ("r", 0), seqs[("r", 0)][:10], 6)
    assert exact == ref


# ---------------------------------------------------------------------------
# Token-identity: speculative scheduler == dense engine
# ---------------------------------------------------------------------------

def test_spec_greedy_identity_dense(rng):
    """draft == target: acceptance 1.0, every pass emits k+1 tokens, and
    the output is token-identical to the non-speculative engine."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (7, 13, 21)]
    news = [6, 9, 5]
    refs = _engine_refs(cfg, params, prompts, news, max_len=96)
    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=96), k=3)
    done, sch = _run_spec(cfg, params, prompts, news, spec, slots=3,
                          max_len=96, block_size=8, chunk=16)
    assert done == refs
    rep = sch.spec_report()
    assert rep["accept_rate"] == 1.0
    assert rep["tokens_per_pass"] == 4.0
    assert sch.pool.blocks_in_use == 0            # no leaked references


def test_spec_identity_independent_of_draft(rng):
    """The §12 exactness claim: a WRONG draft (different weights) and a
    half-corrupted oracle both yield the exact same greedy tokens —
    only the realized acceptance moves."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (9, 16)]
    news = [8, 7]
    refs = _engine_refs(cfg, params, prompts, news, max_len=96)

    other = api.init(jax.random.PRNGKey(99), cfg)   # a real, wrong draft
    spec = SpecConfig(draft=ModelDraft(cfg, other, max_len=96), k=4)
    done, sch = _run_spec(cfg, params, prompts, news, spec, slots=2,
                          max_len=96, block_size=8, chunk=16)
    assert done == refs
    assert sch.spec_report()["accept_rate"] < 1.0

    seqs = {(i, 0): prompts[i] + refs[i] for i in range(len(prompts))}
    spec = SpecConfig(draft=OracleDraft(seqs, accept_rate=0.5,
                                        vocab_size=cfg.vocab_size), k=4)
    done, sch = _run_spec(cfg, params, prompts, news, spec, slots=2,
                          max_len=96, block_size=8, chunk=16)
    assert done == refs
    rep = sch.spec_report()
    assert 0.0 < rep["accept_rate"] < 1.0
    assert 1.0 < rep["tokens_per_pass"] < 5.0


def test_spec_identity_moe(rng):
    # §10 capacity caveat: capacity must not bind for the k+1-token
    # verify groups to be token-exact (same as chunked prefill)
    cfg = get_config("dbrx-132b", smoke=True).replace(
        dtype=jnp.float32, capacity_factor=8.0)
    params = api.init(jax.random.PRNGKey(1), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13)]
    news = [5, 6]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=64), k=3)
    done, _ = _run_spec(cfg, params, prompts, news, spec, slots=2,
                        max_len=64, block_size=8, chunk=8)
    assert done == refs


def test_spec_identity_vlm(rng):
    cfg = get_config("qwen2-vl-2b", smoke=True).replace(dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(2), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 11)]
    news = [5, 6]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=64), k=3)
    done, _ = _run_spec(cfg, params, prompts, news, spec, slots=2,
                        max_len=64, block_size=8, chunk=8)
    assert done == refs


def test_spec_preemption_stays_exact(rng):
    """A pool too small for all slots forces eviction mid-speculation;
    rollback truncation + replay must stay token-identical."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(3), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (20, 22, 25)]
    news = [12, 12, 12]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=64), k=3)
    done, sch = _run_spec(cfg, params, prompts, news, spec, slots=3,
                          max_len=64, block_size=8, num_blocks=13, chunk=8)
    assert done == refs
    assert sch.pool.peak_in_use <= 12
    assert sch.pool.blocks_in_use == 0


def test_spec_eos_mid_pass(rng):
    """EOS landing inside an accepted run must cut the output exactly
    where the engine's one-token loop would have stopped."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(4), cfg)
    prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()
    eng = Engine(cfg, params, max_len=96)
    full = eng.generate(np.asarray([prompt], np.int32),
                        ServeConfig(max_new_tokens=10))[0, 9:].tolist()
    eos = full[4]                     # stop mid-sequence
    want = full[:5]
    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=96), k=4)
    sch = Scheduler(cfg, params, slots=1, max_len=96, block_size=8,
                    chunk=16, spec=spec)
    sch.submit(Request(rid=0, prompt=prompt, max_new=10, eos=eos))
    done = sch.run()
    assert done[0] == want
    assert sch.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Beam forking on COW tables
# ---------------------------------------------------------------------------

def test_beam_forks_bit_match_engine(rng):
    """Each fork must equal an engine run seeded with its first token;
    COW keeps n=4 peak blocks well under 4× a single stream."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(5), cfg)
    # prompt-heavy (the beam-search regime the COW claim is about):
    # the 60-token prompt is stored once, each fork privatizes only its
    # COW'd tail block plus the generated blocks
    prompt = rng.integers(1, cfg.vocab_size, size=60).tolist()
    nb, new, max_len = 4, 8, 128
    eng = Engine(cfg, params, max_len=max_len)

    sch1 = Scheduler(cfg, params, slots=1, max_len=max_len, block_size=8,
                     chunk=16)
    sch1.submit(Request(rid=0, prompt=prompt, max_new=new))
    single = sch1.run()[0]

    sch = Scheduler(cfg, params, slots=nb, max_len=max_len, block_size=8,
                    chunk=16)
    sch.submit(Request(rid=0, prompt=prompt, max_new=new, n_best=nb))
    done = sch.run()
    assert list(done) == [0] and len(done[0]) == nb
    assert done[0][0] == single                   # rank 0 == greedy
    firsts = [out[0] for out in done[0]]
    assert len(set(firsts)) == nb                 # n distinct first tokens
    for out in done[0]:
        forced = eng.generate(
            np.asarray([prompt + [out[0]]], np.int32),
            ServeConfig(max_new_tokens=new - 1)
            )[0, len(prompt) + 1:].tolist()
        assert out[1:] == forced
    # COW memory claim: shared prompt prefix stored once
    assert sch.pool.cow_copies >= 1
    assert sch.pool.peak_in_use < 2 * sch1.pool.peak_in_use
    assert sch.pool.blocks_in_use == 0


def test_beam_with_speculation(rng):
    """Both COW consumers composed: n-best forks each running k-draft
    speculation must still match the engine per rank."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(6), cfg)
    prompt = rng.integers(1, cfg.vocab_size, size=13).tolist()
    nb, new, max_len = 3, 8, 96
    base = Scheduler(cfg, params, slots=nb, max_len=max_len, block_size=8,
                     chunk=16)
    base.submit(Request(rid=0, prompt=prompt, max_new=new, n_best=nb))
    want = base.run()

    spec = SpecConfig(draft=ModelDraft(cfg, params, max_len=max_len), k=3)
    sch = Scheduler(cfg, params, slots=nb, max_len=max_len, block_size=8,
                    chunk=16, spec=spec)
    sch.submit(Request(rid=0, prompt=prompt, max_new=new, n_best=nb))
    assert sch.run() == want
    assert sch.spec_report()["accept_rate"] == 1.0
    assert sch.pool.blocks_in_use == 0


def test_batcher_rejects_n_best():
    from repro.serve.batching import ContinuousBatcher
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, params, slots=2, max_len=32)
    with pytest.raises(AssertionError, match="paged Scheduler"):
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2, n_best=2))
