"""Group-softmax / group-RMSNorm / group-LayerNorm kernels vs oracles,
plus the LUT approximation error bounds the paper's accuracy story
depends on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fusion
from repro.kernels import ref
from repro.kernels.group_rmsnorm import group_layernorm, group_rmsnorm
from repro.kernels.group_softmax import group_softmax


@pytest.mark.parametrize("rows,s,g", [(8, 128, 64), (16, 256, 64),
                                      (8, 512, 128), (32, 64, 32)])
def test_group_softmax_kernel_vs_ref(rng, rows, s, g):
    x = jnp.asarray(rng.standard_normal((rows, s)).astype(np.float32) * 4)
    got = group_softmax(x, g, block_rows=8, interpret=True)
    want = ref.group_softmax_ref(x, g, use_lut=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_group_softmax_lut_close_to_exact(rng):
    x = jnp.asarray(rng.standard_normal((64, 256)).astype(np.float32) * 5)
    lut = np.asarray(fusion.group_softmax(x, 64, use_lut=True))
    exact = np.asarray(jax.nn.softmax(x, axis=-1))
    # chord error of exp on a 0.25-wide segment ≈ w²/8 ≈ 0.8% relative;
    # propagated through the softmax ratio this bounds abs error ≈ 4e-3
    assert np.abs(lut - exact).max() < 4e-3
    np.testing.assert_allclose(lut.sum(-1), 1.0, atol=1e-5)


def test_group_softmax_matches_exact_when_no_lut(rng):
    x = jnp.asarray(rng.standard_normal((8, 200)).astype(np.float32) * 3)
    got = fusion.group_softmax(x, 64, use_lut=False)   # padded path too
    want = jax.nn.softmax(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_lut_exp_error_bound():
    x = jnp.linspace(-16.0, 0.0, 10_001)
    err = jnp.abs(fusion.lut_exp(x) - jnp.exp(x))
    # chord error bound: max |exp - chord| ≤ e^(seg hi)·w²/8 with
    # w = 16/64 = 0.25 → 7.8e-3 on the last segment
    assert float(err.max()) < 8e-3
    # relative error away from the clamp region stays ~sub-percent
    rel = err / jnp.exp(x)
    assert float(rel[x > -10].max()) < 8e-3
    # underflow guard: exact zero below range
    assert float(fusion.lut_exp(jnp.array([-1e9, -17.0])).max()) == 0.0


@pytest.mark.parametrize("rows,n,g", [(8, 256, 64), (16, 128, 128),
                                      (8, 512, 256)])
def test_group_rmsnorm_kernel_vs_ref(rng, rows, n, g):
    x = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    gamma = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = group_rmsnorm(x, gamma, g, interpret=True)
    want = ref.group_rmsnorm_ref(x, gamma, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_group_rmsnorm_equals_global_rmsnorm(rng):
    """eq (2) + late sync is numerically the standard global RMSNorm."""
    x = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
    g = jnp.ones(256)
    got = fusion.group_rmsnorm(x, g, group_size=64)
    inv = jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(got, x * inv, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rows,n,g", [(8, 256, 64), (16, 128, 128)])
def test_group_layernorm_kernel_vs_ref(rng, rows, n, g):
    x = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    gamma = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    beta = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = group_layernorm(x, gamma, beta, g, interpret=True)
    want = ref.group_layernorm_ref(x, gamma, beta, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_online_softmax_attention_matches_exact(rng):
    q = jnp.asarray(rng.standard_normal((2, 2, 32, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 2, 32, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 2, 32, 16)).astype(np.float32))
    got = fusion.online_softmax_attention(q, k, v, causal=True, block_k=8)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
