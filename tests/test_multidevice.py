"""Multi-device distribution features that need >1 device: run in
subprocesses with XLA_FLAGS host placeholder devices (the main test
process must keep seeing 1 device per the task spec)."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(src: str, devices: int = 8, timeout=600):
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(src))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_gpipe_pipeline_matches_sequential():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import pipeline_apply

    S, B, D = 4, 16, 32
    from repro import compat
    mesh = compat.make_mesh((S,), ("stage",),
                            axis_types=(compat.AxisType.Auto,))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((S, D, D)).astype(np.float32) * 0.3)
    bs = jnp.asarray(rng.standard_normal((S, D)).astype(np.float32) * 0.1)
    x = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))

    def stage(params, h):
        w, b = params
        return jnp.tanh(h @ w + b)

    with compat.set_mesh(mesh):
        y = pipeline_apply(mesh, stage, (ws, bs), x, n_micro=4)

    ref = x
    for i in range(S):
        ref = jnp.tanh(ref @ ws[i] + bs[i])
    err = float(jnp.abs(y - ref).max())
    assert err < 1e-5, err
    print("gpipe ok", err)
    """)
    assert "gpipe ok" in out


def test_compressed_pod_psum_error_bound():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.compress import compressed_psum

    from repro import compat
    mesh = compat.make_mesh((4, 2), ("pod", "data"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"),),
             out_specs=P("pod"), check_rep=False)
    def f(x):
        return compressed_psum(x, "pod")

    with compat.set_mesh(mesh):
        got = f(g)
    # every pod shard now holds the sum over the pod axis
    want = jnp.broadcast_to(g.sum(0, keepdims=True), g.shape)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel < 2e-2, rel   # int8 chunk-scaled error bound
    print("compressed psum ok", rel)
    """)
    assert "compressed psum ok" in out


def test_sharded_train_step_multidevice():
    """The jitted sharded train step runs (not just compiles) on an 8-dev
    (4 data × 2 model) host mesh with FSDP+TP rules."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import TrainConfig, Trainer
    from repro.train.optimizer import OptConfig

    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    from repro import compat
    mesh = compat.make_mesh((4, 2), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    dc = DataConfig(seed=0, batch_size=8, seq_len=32,
                    vocab_size=cfg.vocab_size)
    tr = Trainer(cfg, mesh, dc, TrainConfig(total_steps=6),
                 OptConfig(lr=1e-3))
    losses = []
    tr.run(on_metrics=lambda s, m: losses.append(m["loss"]))
    print("multidev train ok")
    """)
    assert "multidev train ok" in out
