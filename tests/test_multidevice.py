"""Multi-device distribution features that need >1 device: run in
subprocesses with XLA_FLAGS host placeholder devices (the main test
process must keep seeing 1 device per the task spec)."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(src: str, devices: int = 8, timeout=600):
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(src))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_gpipe_pipeline_matches_sequential():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import pipeline_apply

    S, B, D = 4, 16, 32
    from repro import compat
    mesh = compat.make_mesh((S,), ("stage",),
                            axis_types=(compat.AxisType.Auto,))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((S, D, D)).astype(np.float32) * 0.3)
    bs = jnp.asarray(rng.standard_normal((S, D)).astype(np.float32) * 0.1)
    x = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))

    def stage(params, h):
        w, b = params
        return jnp.tanh(h @ w + b)

    with compat.set_mesh(mesh):
        y = pipeline_apply(mesh, stage, (ws, bs), x, n_micro=4)

    ref = x
    for i in range(S):
        ref = jnp.tanh(ref @ ws[i] + bs[i])
    err = float(jnp.abs(y - ref).max())
    assert err < 1e-5, err
    print("gpipe ok", err)
    """)
    assert "gpipe ok" in out


def test_compressed_pod_psum_error_bound():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.compress import compressed_psum

    from repro import compat
    mesh = compat.make_mesh((4, 2), ("pod", "data"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"),),
             out_specs=P("pod"), check_rep=False)
    def f(x):
        return compressed_psum(x, "pod")

    with compat.set_mesh(mesh):
        got = f(g)
    # every pod shard now holds the sum over the pod axis
    want = jnp.broadcast_to(g.sum(0, keepdims=True), g.shape)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel < 2e-2, rel   # int8 chunk-scaled error bound
    print("compressed psum ok", rel)
    """)
    assert "compressed psum ok" in out


# ---------------------------------------------------------------------------
# PR 8: multi-device paged serving (DESIGN.md §13)
# ---------------------------------------------------------------------------

_PAGED_COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.engine import Engine, ServeConfig
    from repro.serve.paged import DisaggScheduler, Scheduler
    from repro.serve.spec_decode import OracleDraft, SpecConfig

    def engine_refs(cfg, params, prompts, news, max_len):
        eng = Engine(cfg, params, max_len=max_len)
        return {i: eng.generate(np.asarray([p], np.int32),
                                ServeConfig(max_new_tokens=n)
                                )[0, len(p):].tolist()
                for i, (p, n) in enumerate(zip(prompts, news))}

    def run_sched(cfg, params, prompts, news, **kw):
        sch = Scheduler(cfg, params, **kw)
        for i, (p, n) in enumerate(zip(prompts, news)):
            sch.submit(Request(rid=i, prompt=p, max_new=n))
        return sch.run(), sch

    def sweep(cfg, params, prompts, news, refs, mesh, max_len, k=3):
        # slots 4/16 × {plain, speculative}: every arm must reproduce
        # the single-device PR 7 engine exactly
        refseqs = {(i, 0): prompts[i] + refs[i] for i in refs}
        for slots in (4, 16):
            for rate in (None, 0.6, 1.0):
                spec = None if rate is None else SpecConfig(
                    draft=OracleDraft(refseqs, accept_rate=rate,
                                      vocab_size=cfg.vocab_size), k=k)
                done, sch = run_sched(
                    cfg, params, prompts, news, slots=slots,
                    max_len=max_len, block_size=8, chunk=8,
                    spec=spec, mesh=mesh)
                assert done == refs, (slots, rate)
        return sch
"""


def test_paged_sharded_identity_dense_sweep_and_disagg():
    """8-way host mesh, data=4 (smoke llama has 4 kv heads → 4 shards):
    the sharded paged scheduler sweep (slots 4/16, ± speculative decode)
    and the disaggregated prefill/decode split are token-identical to
    the single-device engine."""
    out = _run(_PAGED_COMMON + """
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 19, 9, 26, 5, 13, 17, 8)]
    news = [6, 8, 5, 7, 6, 8, 5, 7]
    refs = engine_refs(cfg, params, prompts, news, max_len=128)

    mesh = make_serving_mesh(data=4).mesh
    sch = sweep(cfg, params, prompts, news, refs, mesh, max_len=128)
    assert sch.data_shards() == 4, sch.data_shards()
    assert sch.per_device_peak_blocks() == sch.pool.peak_in_use / 4

    dm = make_serving_mesh(data=4, prefill_data=2)
    dis = DisaggScheduler(cfg, params, prefill_mesh=dm.prefill_mesh,
                          decode_mesh=dm.mesh, slots=4, max_len=128,
                          block_size=8, chunk=8)
    for i, (p, n) in enumerate(zip(prompts, news)):
        dis.submit(Request(rid=i, prompt=p, max_new=n))
    assert dis.run() == refs
    assert dis.handoffs == len(prompts)
    print("dense sweep ok", sch.data_shards(), dis.handoffs)
    """, timeout=1800)
    assert "dense sweep ok" in out


def test_paged_sharded_identity_moe_sweep():
    """MoE (2 kv heads → 2-way data sharding; capacity unbinding per
    DESIGN.md §10) sweep vs the single-device engine."""
    out = _run(_PAGED_COMMON + """
    cfg = get_config("dbrx-132b", smoke=True).replace(
        dtype=jnp.float32, capacity_factor=8.0)
    params = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9, 17)]
    news = [5, 6, 4, 6]
    refs = engine_refs(cfg, params, prompts, news, max_len=64)
    mesh = make_serving_mesh(data=2).mesh
    sch = sweep(cfg, params, prompts, news, refs, mesh, max_len=64)
    assert sch.data_shards() == 2, sch.data_shards()
    print("moe sweep ok")
    """, timeout=1800)
    assert "moe sweep ok" in out


def test_paged_sharded_identity_vlm_sweep():
    """VLM (2 kv heads → 2-way data sharding) sweep vs the
    single-device engine."""
    out = _run(_PAGED_COMMON + """
    cfg = get_config("qwen2-vl-2b", smoke=True).replace(dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9, 17)]
    news = [5, 6, 4, 6]
    refs = engine_refs(cfg, params, prompts, news, max_len=64)
    mesh = make_serving_mesh(data=2).mesh
    sch = sweep(cfg, params, prompts, news, refs, mesh, max_len=64)
    assert sch.data_shards() == 2, sch.data_shards()
    print("vlm sweep ok")
    """, timeout=1800)
    assert "vlm sweep ok" in out


def test_paged_pool_sharding_layout_and_baseline_flag():
    """The §13 placement facts: pools shard kv_heads over "data" (per-
    device bytes = total/data), block tables replicate, and
    REPRO_OPT_SHARDKV=0 yields fully-replicated pools (data_shards 1)."""
    out = _run("""
    import os, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import api

    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    mesh = make_serving_mesh(data=4).mesh
    cache = api.init_cache(cfg, 4, 128, num_blocks=40, block_size=8,
                           mesh=mesh)
    k = cache["k"]                    # (L, NB, BS, Hkv, D)
    shard = k.sharding.shard_shape(k.shape)
    assert shard == (k.shape[0], k.shape[1], k.shape[2],
                     k.shape[3] // 4, k.shape[4]), shard
    bt = cache["bt"]
    assert bt.sharding.shard_shape(bt.shape) == bt.shape  # replicated

    os.environ["REPRO_OPT_SHARDKV"] = "0"
    cache0 = api.init_cache(cfg, 4, 128, num_blocks=40, block_size=8,
                            mesh=mesh)
    k0 = cache0["k"]
    assert k0.sharding.shard_shape(k0.shape) == k0.shape  # replicated
    print("layout ok")
    """)
    assert "layout ok" in out


def test_shard_map_paged_kernels_bit_identical():
    """The shard_map adapters (parallel.shard_kernels) running the
    interpret-mode Pallas paged kernels with heads split over "model"
    are BIT-identical to the unsharded kernel — per-(b, h) programs are
    independent and contiguous splits keep GQA groups whole."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.kernels import ops
    from repro.parallel import shard_kernels as sk

    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    B, H, Hkv, D, NB, BS, NBMAX, C = 2, 8, 4, 16, 9, 8, 4, 8
    kp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, NB, size=(B, NBMAX)).astype(np.int32))
    q1 = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    ln = jnp.asarray(np.array([17, 29], np.int32))
    qc = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    st = jnp.asarray(np.array([8, 16], np.int32))

    assert sk.head_shard_axis(mesh, H, Hkv) == "model"
    ops.force_pallas(True)
    try:
        want_d = ops.paged_attention_decode(q1, kp, vp, bt, ln,
                                            group_size=8)
        want_p = ops.paged_flash_prefill(qc, kp, vp, bt, st)
        with compat.set_mesh(mesh):
            got_d = sk.sharded_paged_attention_decode(
                mesh, "model", q1, kp, vp, bt, ln, group_size=8)
            got_p = sk.sharded_paged_flash_prefill(
                mesh, "model", qc, kp, vp, bt, st)
            # and the ops-level dispatch routes through shard_map on its
            # own when the mesh is ambient
            auto_d = ops.paged_attention_decode(q1, kp, vp, bt, ln,
                                                group_size=8)
    finally:
        ops.force_pallas(None)
    assert jnp.array_equal(want_d, got_d)
    assert jnp.array_equal(want_p, got_p)
    assert jnp.array_equal(want_d, auto_d)
    print("shard_map kernels ok")
    """)
    assert "shard_map kernels ok" in out


def test_sharded_train_step_multidevice():
    """The jitted sharded train step runs (not just compiles) on an 8-dev
    (4 data × 2 model) host mesh with FSDP+TP rules."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import TrainConfig, Trainer
    from repro.train.optimizer import OptConfig

    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    from repro import compat
    mesh = compat.make_mesh((4, 2), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    dc = DataConfig(seed=0, batch_size=8, seq_len=32,
                    vocab_size=cfg.vocab_size)
    tr = Trainer(cfg, mesh, dc, TrainConfig(total_steps=6),
                 OptConfig(lr=1e-3))
    losses = []
    tr.run(on_metrics=lambda s, m: losses.append(m["loss"]))
    print("multidev train ok")
    """)
    assert "multidev train ok" in out
