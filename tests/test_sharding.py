"""Sharding-rule resolution: divisibility fallback, no mesh-axis reuse
within a tensor, full-config spec coverage for every arch on an abstract
production-shaped mesh."""
import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models import api
from repro.models.layers import is_axes_leaf
from repro.parallel import sharding as sh


def _fake_mesh(shape=(4, 2), axes=("data", "model")):
    n = math.prod(shape)
    if len(jax.devices()) >= n:
        return jax.make_mesh(shape, axes)
    # abstract mesh stand-in with a .shape mapping is enough for spec_for
    from repro.compat import abstract_mesh
    return abstract_mesh(shape, axes)


def test_divisible_dims_shard():
    mesh = _fake_mesh()
    spec = sh.spec_for(("embed", "mlp"), (64, 128), mesh, sh.TRAIN_RULES)
    assert spec == P("data", "model")


def test_non_divisible_falls_back_to_replicated():
    mesh = _fake_mesh()
    spec = sh.spec_for(("embed", "mlp"), (63, 127), mesh, sh.TRAIN_RULES)
    assert spec == P(None, None)


def test_no_mesh_axis_reuse():
    mesh = _fake_mesh()
    # ("inner","inner"): both want "model"; the second must not reuse it
    spec = sh.spec_for(("inner", "inner"), (64, 64), mesh, sh.TRAIN_RULES)
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_batch_multi_axis():
    mesh = _fake_mesh((2, 4, 2), ("pod", "data", "model"))
    spec = sh.spec_for(("batch", None), (16, 8), mesh, sh.TRAIN_RULES)
    assert spec[0] == ("pod", "data")


def test_serve_rules_replicate_embed():
    mesh = _fake_mesh()
    spec = sh.spec_for(("embed", "qkv"), (64, 64), mesh, sh.SERVE_RULES)
    assert spec == P(None, "model")


def test_kv_heads_falls_through_to_head_dim():
    mesh = _fake_mesh((2, 16), ("data", "model"))
    # whisper: 20 kv heads do NOT divide the 16-way model axis (and jit
    # in_shardings rejects uneven sharding) → head_dim carries the TP shard
    spec = sh.spec_for(("layers", "batch", "seq", "kv_heads", "head_dim"),
                       (2, 4, 64, 20, 64), mesh, sh.SERVE_RULES)
    assert spec[3] is None and spec[4] == "model"


@pytest.mark.parametrize("arch", list(list_archs()))
def test_full_config_spec_coverage(arch):
    """Every full-size param resolves to a valid spec on the production
    mesh shape; TP must actually shard the big matmuls."""
    cfg = get_config(arch)
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    ax = api.axes(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
    flat_ax = jax.tree.leaves(ax, is_leaf=is_axes_leaf)
    flat_sh = jax.tree.leaves(shapes)
    n_model_sharded = 0
    big_params = 0
    for a, s in zip(flat_ax, flat_sh):
        spec = sh.spec_for(a, s.shape, mesh, sh.TRAIN_RULES)
        # no axis reuse
        flat = []
        for part in spec:
            if part is None:
                continue
            flat.extend(part if isinstance(part, tuple) else (part,))
        assert len(flat) == len(set(flat)), (arch, a, s.shape, spec)
        if int(np.prod(s.shape)) >= 1_000_000:
            big_params += 1
            if "model" in flat:
                n_model_sharded += 1
    assert big_params > 0
    # at least 80% of big tensors are TP-sharded
    assert n_model_sharded / big_params >= 0.8, arch
