"""Paged-KV serving subsystem (DESIGN.md §10/§11): block-pool
bookkeeping, the paged fused decode kernel vs its jnp oracle, the paged
flash-prefill kernel vs its oracles, pool write/gather round-trips,
token-for-token equivalence of the chunked-prefill Scheduler against
``Engine.generate`` on dense / MoE / VLM configs with skewed prompt
lengths, shared prefixes, and preemption, and the PR 6 chunk-step
dispatch accounting (kernel-resident prefill hot loop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.paged_attention_decode import paged_attention_decode
from repro.kernels.paged_flash_prefill import paged_flash_prefill
from repro.models import api
from repro.models import layers as L
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import Engine, ServeConfig
from repro.serve.paged import KVBlockPool, Scheduler, prefix_hashes


# ---------------------------------------------------------------------------
# KVBlockPool
# ---------------------------------------------------------------------------

def test_pool_alloc_release_refcount():
    pool = KVBlockPool(num_blocks=4, block_size=8)    # 3 usable (0 = null)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert sorted([a, b, c]) == [1, 2, 3] and pool.alloc() is None
    assert pool.blocks_in_use == 3 and pool.peak_in_use == 3
    pool.retain(b)
    pool.release(b)
    assert pool.alloc() is None                       # b still referenced
    pool.release(b)
    assert pool.alloc() == b                          # unhashed → free list
    pool.release(a)
    pool.release(c)
    assert pool.num_free == 2


def test_pool_prefix_cache_reuse_and_eviction():
    pool = KVBlockPool(num_blocks=4, block_size=2)
    toks = [5, 6, 7, 8, 9]
    h = prefix_hashes(toks, 2)
    assert len(h) == 2                                # full blocks only
    a, b = pool.alloc(), pool.alloc()
    pool.register_prefix(a, h[0])
    pool.register_prefix(b, h[1])
    assert pool.match_prefix(toks) == [a, b]
    assert pool.match_prefix([5, 6, 0, 0]) == [a]     # chain stops at miss
    pool.release(a)
    pool.release(b)                                   # → cached, evictable
    assert pool.num_free == 3
    got = pool.match_prefix(toks)
    assert got == [a, b]
    pool.retain(a)                                    # revive from cache
    c, d = pool.alloc(), pool.alloc()                 # free list then LRU
    assert c == 3 and d == b                          # b evicted (a live)
    assert pool.match_prefix(toks) == [a]             # chain cut at b
    # first-writer-wins: an already-mapped hash keeps its block
    pool.register_prefix(c, h[0])
    assert pool.lookup_prefix(h[0]) == a


def test_pool_lru_eviction_ordering():
    """Cached (refcount-0, hashed) blocks must be reclaimed in
    least-recently-released order, and reviving a block (retain) must
    pull it out of the eviction queue entirely."""
    pool = KVBlockPool(num_blocks=5, block_size=2)
    a, b, c, d = (pool.alloc() for _ in range(4))
    for bid, h in ((a, 101), (b, 102), (c, 103), (d, 104)):
        pool.register_prefix(bid, h)
    # release in a scrambled order: c first, then a, then d, then b
    for bid in (c, a, d, b):
        pool.release(bid)
    pool.retain(d)                       # revive d — no longer evictable
    got = [pool.alloc() for _ in range(3)]
    assert got == [c, a, b]              # LRU order, d skipped
    assert pool.evictions == 3
    assert pool.alloc() is None          # d still live, pool dry
    # evicted blocks lost their hashes; d kept its mapping
    assert pool.lookup_prefix(103) is None
    assert pool.lookup_prefix(104) == d


def test_pool_prefix_stats_counters():
    pool = KVBlockPool(num_blocks=6, block_size=2)
    toks = [5, 6, 7, 8, 9, 10]
    h = prefix_hashes(toks, 2)
    a, b = pool.alloc(), pool.alloc()
    pool.register_prefix(a, h[0])
    pool.register_prefix(b, h[1])
    assert pool.match_prefix(toks) == [a, b]
    # 2 hits + 1 miss (the probe for the unregistered third block)
    assert (pool.prefix_hits, pool.prefix_misses) == (2, 1)
    assert pool.match_prefix([5, 6, 0, 0]) == [a]
    assert (pool.prefix_hits, pool.prefix_misses) == (3, 2)
    assert pool.match_prefix([0, 0]) == []
    assert (pool.prefix_hits, pool.prefix_misses) == (3, 3)
    # a full-block-aligned prompt that fully matches ends on a hit with
    # no trailing miss (there is no probe past its last block)
    assert pool.match_prefix(toks[:4]) == [a, b]
    assert (pool.prefix_hits, pool.prefix_misses) == (5, 3)
    assert pool.stats == {"prefix_hits": 5, "prefix_misses": 3,
                          "prefix_hit_rate": 5 / 8,
                          "evictions": 0, "cow_copies": 0,
                          "peak_in_use": 2, "blocks_in_use": 2,
                          "num_free": 3, "cached_blocks": 0,
                          "fragmentation": 0.0,
                          "largest_admissible_tokens": 4}


def test_pool_stats_reset_and_high_water():
    """reset_stats() zeroes the counters and re-bases the occupancy
    high-water mark at the CURRENT occupancy, so back-to-back benchmark
    arms on one pool don't inherit each other's peaks (PR 8)."""
    pool = KVBlockPool(num_blocks=8, block_size=2)
    blocks = [pool.alloc() for _ in range(4)]
    assert pool.peak_in_use == 4
    for b in blocks[2:]:
        pool.release(b)
    assert pool.peak_in_use == 4 and pool.blocks_in_use == 2
    pool.reset_stats()
    assert pool.peak_in_use == 2          # re-based, not zeroed
    assert (pool.prefix_hits, pool.prefix_misses,
            pool.evictions, pool.cow_copies) == (0, 0, 0, 0)
    pool.alloc()
    assert pool.peak_in_use == 3


def test_pool_fragmentation_and_reset_interaction():
    """The live-state derived stats (fragmentation, cached_blocks,
    largest_admissible_tokens) reflect CURRENT pool shape and survive
    reset_stats(); the counter-derived prefix_hit_rate restarts at 0
    (PR 10 §15 — the telemetry gauges fold pool.stats verbatim)."""
    pool = KVBlockPool(num_blocks=6, block_size=4)    # 5 usable
    toks = [3, 4, 5, 6, 7, 8, 9, 10]
    h = prefix_hashes(toks, 4)
    a, b = pool.alloc(), pool.alloc()
    pool.register_prefix(a, h[0])
    pool.register_prefix(b, h[1])
    pool.release(a)
    pool.release(b)                       # both parked in the LRU cache
    assert pool.match_prefix(toks) == [a, b]
    st = pool.stats
    assert st["cached_blocks"] == 2 and st["num_free"] == 5
    assert st["fragmentation"] == 2 / 5
    # every free block counts toward admissibility (cached ones via
    # eviction), minus the decode-headroom block
    assert st["largest_admissible_tokens"] == 16
    assert st["prefix_hit_rate"] == 1.0
    pool.reset_stats()
    st = pool.stats
    # counters reset...
    assert st["prefix_hits"] == 0 and st["prefix_hit_rate"] == 0.0
    # ...but live-state stats persist: the cache didn't go anywhere
    assert st["cached_blocks"] == 2 and st["fragmentation"] == 2 / 5
    assert st["largest_admissible_tokens"] == 16
    # allocating past the free list evicts from the cache → less
    # fragmentation, same admissibility math on the shrunk num_free
    for _ in range(4):
        assert pool.alloc() is not None
    st = pool.stats
    assert st["cached_blocks"] == 1 and st["num_free"] == 1
    assert st["fragmentation"] == 1.0     # only evictable capacity left
    assert st["largest_admissible_tokens"] == 0
    assert pool.evictions == 1            # post-reset counter counts again


def test_pool_cow_fork_primitives():
    """fork bumps refcounts without moving KV; writable demands sole
    ownership AND no published hash; cow trades a reference for a fresh
    block (or None on a dry pool, leaving the reference intact)."""
    pool = KVBlockPool(num_blocks=5, block_size=4)
    a, b = pool.alloc(), pool.alloc()
    pool.register_prefix(a, 201)
    table = pool.fork([a, b])
    assert table == [a, b]
    assert pool.refcount(a) == 2 and pool.refcount(b) == 2
    assert not pool.writable(a) and not pool.writable(b)
    new = pool.cow(b)                    # shared, unhashed → COW
    assert new is not None and new not in (a, b)
    assert pool.refcount(b) == 1 and pool.refcount(new) == 1
    assert pool.writable(b) and pool.writable(new)
    # hashed blocks stay unwritable even at refcount 1 (the hash
    # describes the current bytes — writing would poison the cache)
    pool.release(a)
    assert pool.refcount(a) == 1 and not pool.writable(a)
    assert pool.cow_copies == 1
    # dry pool: cow fails cleanly, reference untouched
    c = pool.alloc()
    assert c is not None and pool.alloc() is None
    pool.retain(c)
    assert pool.cow(c) is None and pool.refcount(c) == 2


# ---------------------------------------------------------------------------
# Paged kernel vs oracle
# ---------------------------------------------------------------------------

def _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, lens):
    kp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))
    bt = np.zeros((B, NBMAX), np.int32)
    nxt = 1
    for b, n in enumerate(lens):
        for j in range(-(-n // BS)):
            bt[b, j] = nxt
            nxt += 1
    assert nxt <= NB
    return kp, vp, jnp.asarray(bt)


@pytest.mark.parametrize("use_lut,group", [(True, 16), (True, 8),
                                           (False, 16), (False, 64)])
def test_paged_kernel_vs_oracle(rng, use_lut, group):
    B, H, Hkv, D, NB, BS, NBMAX = 3, 4, 2, 32, 16, 16, 4
    lens = [41, 17, 64]
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, lens)
    lengths = jnp.asarray(lens, jnp.int32)
    got = paged_attention_decode(q, kp, vp, bt, lengths, group_size=group,
                                 use_lut=use_lut, interpret=True)
    # the kernel caps the softmax group at the block size
    want = ref.paged_attention_decode_ref(q, kp, vp, bt, lengths,
                                          group_size=min(group, BS),
                                          use_lut=use_lut)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    if not use_lut:
        # exact-exp grouping invariance: same answer as the full-group
        # oracle to fp32 round-off (DESIGN.md §4)
        want64 = ref.paged_attention_decode_ref(q, kp, vp, bt, lengths,
                                                group_size=64, use_lut=False)
        np.testing.assert_allclose(got, want64, rtol=2e-5, atol=2e-5)


def test_paged_kernel_window(rng):
    B, H, Hkv, D, NB, BS, NBMAX = 2, 4, 2, 32, 12, 16, 5
    lens = [70, 33]
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, lens)
    lengths = jnp.asarray(lens, jnp.int32)
    got = paged_attention_decode(q, kp, vp, bt, lengths, group_size=16,
                                 use_lut=False, window=24, interpret=True)
    want = ref.paged_attention_decode_ref(q, kp, vp, bt, lengths,
                                          group_size=16, use_lut=False,
                                          window=24)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_ref_matches_dense_composition(rng):
    """Gathering the pool through the table and masking by length must
    reproduce the dense decode composition bit-for-bit — the property the
    Scheduler's token-identity rests on."""
    B, H, Hkv, D, NB, BS, NBMAX = 2, 4, 2, 16, 10, 8, 8
    lens = [13, 40]
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, lens)
    lengths = jnp.asarray(lens, jnp.int32)
    kg = ref.gather_paged_kv_ref(kp, bt)
    vg = ref.gather_paged_kv_ref(vp, bt)
    want = ref.attention_decode_ref(q, kg, vg, lengths, group_size=64,
                                    use_lut=False)
    got = ref.paged_attention_decode_ref(q, kp, vp, bt, lengths,
                                         group_size=64, use_lut=False)
    assert (np.asarray(got) == np.asarray(want)).all()


# ---------------------------------------------------------------------------
# Paged flash-prefill kernel vs oracles (DESIGN.md §11)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("starts,window", [([25, 48], None), ([0, 32], None),
                                           ([25, 48], 20)])
def test_paged_flash_prefill_kernel_vs_oracle(rng, starts, window):
    B, H, Hkv, D, NB, BS, NBMAX, C = 2, 4, 2, 32, 12, 16, 4, 16
    q = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, [64, 64])
    st = jnp.asarray(starts, jnp.int32)
    got = paged_flash_prefill(q, kp, vp, bt, st, window=window,
                              interpret=True)
    want = ref.paged_flash_prefill_ref(q, kp, vp, bt, st, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    scan = ref.paged_flash_prefill_scan_ref(q, kp, vp, bt, st,
                                            window=window)
    np.testing.assert_allclose(scan, want, rtol=2e-5, atol=2e-5)


def test_paged_flash_prefill_multi_qblock_and_gqa(rng):
    """C spanning several q blocks, H > Hkv head sharing."""
    B, H, Hkv, D, NB, BS, NBMAX, C = 2, 8, 2, 32, 12, 16, 5, 32
    q = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, [80, 80])
    st = jnp.asarray([13, 48], jnp.int32)
    got = paged_flash_prefill(q, kp, vp, bt, st, block_q=16,
                              interpret=True)
    want = ref.paged_flash_prefill_ref(q, kp, vp, bt, st)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_flash_prefill_lut_tolerance(rng):
    """LUT mode under the flash running rescale: agrees with the exact
    oracle only to LUT tolerance (DESIGN.md §11)."""
    B, H, Hkv, D, NB, BS, NBMAX, C = 1, 2, 2, 32, 8, 16, 4, 16
    q = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, [64])
    st = jnp.asarray([30], jnp.int32)
    got = paged_flash_prefill(q, kp, vp, bt, st, use_lut=True,
                              interpret=True)
    want = ref.paged_flash_prefill_ref(q, kp, vp, bt, st)
    assert float(jnp.abs(got - want).max()) < 2e-2
    scan = ref.paged_flash_prefill_scan_ref(q, kp, vp, bt, st, use_lut=True)
    assert float(jnp.abs(scan - want).max()) < 2e-2


def test_paged_flash_oracle_is_pr5_chunk_path(rng):
    """The golden oracle IS the PR 5 composition: gather the pool dense,
    run the exact materialized offset-causal oracle — bit-for-bit (the
    Scheduler token-identity chain rests on this)."""
    B, H, Hkv, D, NB, BS, NBMAX, C = 2, 4, 2, 16, 10, 8, 8, 8
    q = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, NB, BS, NBMAX, [40, 24])
    st = jnp.asarray([12, 7], jnp.int32)
    kg = jnp.swapaxes(ref.gather_paged_kv_ref(kp, bt), 1, 2)
    vg = jnp.swapaxes(ref.gather_paged_kv_ref(vp, bt), 1, 2)
    want = ref.attention_ref(q, kg, vg, causal=True, q_offset=st)
    got = ref.paged_flash_prefill_ref(q, kp, vp, bt, st)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_untileable_chunk_raises_instead_of_densifying():
    """On the kernel path, shapes the grid cannot tile must RAISE, not
    silently fall back to the dense oracle — and the message must name
    the offending shapes and the chosen block sizes, so the fix (pad or
    re-block) is readable straight off the exception."""
    rng = np.random.default_rng(0)
    B, H, Hkv, D = 1, 2, 2, 32
    q = jnp.asarray(rng.standard_normal((B, H, 24, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, 48, D)).astype(np.float32))
    off = jnp.asarray([10], jnp.int32)
    kp, vp, bt = _paged_kv(rng, B, Hkv, D, 8, 16, 4, [48])
    ops.force_pallas(True)
    try:
        with pytest.raises(ValueError, match="densify") as ei:
            ops.attention(q, k, k, q_offset=off, block_q=16, block_k=16)
        msg = str(ei.value)
        assert "(1, 2, 24, 32)" in msg and "(1, 2, 48, 32)" in msg
        assert "block_q=16" in msg and "Sq=24" in msg
        with pytest.raises(ValueError, match="densify") as ei:
            ops.paged_flash_prefill(q, kp, vp, bt, off, block_q=16)
        msg = str(ei.value)
        assert "(1, 2, 24, 32)" in msg and "(8, 16, 2, 32)" in msg
        assert "block_q=16" in msg and "C=24" in msg
        # dividing block sizes pass through to the kernels
        ops.attention(q, k, k, q_offset=off, block_q=8, block_k=16)
        ops.paged_flash_prefill(q[:, :, :16], kp, vp, bt, off)
    finally:
        ops.force_pallas(None)


# ---------------------------------------------------------------------------
# Pool write / gather round-trip (model-layer plumbing)
# ---------------------------------------------------------------------------

def test_write_gather_roundtrip(rng):
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    B, NB, BS, max_len = 2, 9, 8, 32
    cache = L.make_paged_attn_cache(cfg, B, NB, BS, max_len,
                                    dtype=jnp.float32)
    bt = np.zeros((B, max_len // BS), np.int32)
    bt[0, :2] = [1, 3]
    bt[1, :2] = [2, 4]
    cache["bt"] = jnp.asarray(bt)
    Hkv, D = cfg.num_kv_heads, cfg.head_dim_
    k = jnp.asarray(rng.standard_normal((B, 5, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, 5, Hkv, D)).astype(np.float32))
    # write a 5-token chunk starting at position 6 → spans both blocks
    cache = L.write_kv_cache_paged(cache, k, v, jnp.asarray([6, 6]))
    kg, _ = L.gather_paged_kv(cache)
    np.testing.assert_array_equal(np.asarray(kg[:, 6:11]), np.asarray(k))
    # null block (0) untouched by in-range writes
    assert float(jnp.abs(cache["k"][0]).max()) == 0.0
    # positions past the table land in the null block, not a live one
    live_before = np.asarray(cache["k"][1:5])
    cache = L.write_kv_cache_paged(cache, k, v, jnp.asarray([28, 28]))
    np.testing.assert_array_equal(np.asarray(cache["k"][1:5]), live_before)
    assert float(jnp.abs(cache["k"][0]).max()) > 0.0   # null absorbed it


# ---------------------------------------------------------------------------
# Scheduler vs Engine token-identity
# ---------------------------------------------------------------------------

def _engine_refs(cfg, params, prompts, news, max_len):
    eng = Engine(cfg, params, max_len=max_len)
    return {i: eng.generate(np.asarray([p], np.int32),
                            ServeConfig(max_new_tokens=n)
                            )[0, len(p):].tolist()
            for i, (p, n) in enumerate(zip(prompts, news))}


def _run_sched(cfg, params, prompts, news, **kw):
    sch = Scheduler(cfg, params, **kw)
    for i, (p, n) in enumerate(zip(prompts, news)):
        sch.submit(Request(rid=i, prompt=p, max_new=n))
    return sch.run(), sch


def _run_batcher(cfg, params, prompts, news, slots, max_len):
    cb = ContinuousBatcher(cfg, params, slots=slots, max_len=max_len)
    for i, (p, n) in enumerate(zip(prompts, news)):
        cb.submit(Request(rid=i, prompt=p, max_new=n))
    return cb.run()


def test_scheduler_matches_engine_dense_skewed_shared_prefix(rng):
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(0), cfg)
    sysp = rng.integers(1, cfg.vocab_size, size=18).tolist()
    prompts = [sysp + rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (3, 21, 9, 40, 1)]
    prompts.append(rng.integers(1, cfg.vocab_size, size=5).tolist())
    news = [5, 7, 4, 6, 8, 5]
    refs = _engine_refs(cfg, params, prompts, news, max_len=96)
    done, sch = _run_sched(cfg, params, prompts, news, slots=3, max_len=96,
                           block_size=8, num_blocks=20, chunk=16)
    assert done == refs
    assert _run_batcher(cfg, params, prompts, news, 3, 96) == refs
    # the acceptance criterion's memory claim: measurably fewer KV bytes
    # than the slots × max_len dense allocation
    assert sch.kv_bytes_peak() < sch.kv_bytes_dense_equiv()
    assert sch.pool.peak_in_use < sch.n_slots * sch.nbmax
    # the shared 18-token system prefix was stored once: two full shared
    # blocks cover it, so peak usage undershoots the no-sharing total
    assert sch.stream_amortization_report()["mean_active"] > 1.0


def test_scheduler_matches_engine_moe(rng):
    # capacity must not bind for chunked prefill to be token-exact
    # (GShard capacity competition is grouping-dependent, DESIGN.md §10)
    cfg = get_config("dbrx-132b", smoke=True).replace(
        dtype=jnp.float32, capacity_factor=8.0)
    params = api.init(jax.random.PRNGKey(1), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9)]
    news = [5, 4, 6]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    done, _ = _run_sched(cfg, params, prompts, news, slots=2, max_len=64,
                         block_size=8, chunk=8)
    assert done == refs
    assert _run_batcher(cfg, params, prompts, news, 2, 64) == refs


def test_scheduler_matches_engine_vlm(rng):
    cfg = get_config("qwen2-vl-2b", smoke=True).replace(dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(2), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9)]
    news = [5, 4, 6]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    done, _ = _run_sched(cfg, params, prompts, news, slots=2, max_len=64,
                         block_size=8, chunk=8)
    assert done == refs
    assert _run_batcher(cfg, params, prompts, news, 2, 64) == refs


def test_scheduler_preemption_by_eviction_stays_exact(rng):
    """A pool too small for all slots forces mid-decode preemption; the
    evicted request re-prefills (prompt + already-emitted tokens) and
    must still match the uninterrupted reference."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(3), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (20, 22, 25)]
    news = [12, 12, 12]
    refs = _engine_refs(cfg, params, prompts, news, max_len=64)
    done, sch = _run_sched(cfg, params, prompts, news, slots=3, max_len=64,
                           block_size=8, num_blocks=11, chunk=8)
    assert done == refs
    assert sch.pool.peak_in_use <= 10      # never exceeded the tiny pool


def test_final_chunk_padding_past_max_len_stays_exact(rng):
    """A prompt whose last (padded) chunk crosses max_len: the overflow
    positions must land in the null block, not clip onto the request's
    last live block (regression: clipped junk rows won the duplicate-
    index scatter and corrupted the newest K/V)."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(6), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=23).tolist()]
    refs = _engine_refs(cfg, params, prompts, [2], max_len=24)
    done, _ = _run_sched(cfg, params, prompts, [2], slots=1, max_len=24,
                         block_size=8, chunk=16)
    assert done == refs


def test_admission_budget_counts_retained_cached_blocks(rng):
    """Cached prefix blocks are allocatable (in num_free) until retained;
    admission must discount the ones it is about to retain (regression:
    the old check over-admitted and crashed on a failed alloc)."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(7), cfg)
    prompt = rng.integers(1, cfg.vocab_size, size=17).tolist()
    other = rng.integers(1, cfg.vocab_size, size=17).tolist()
    refs = _engine_refs(cfg, params, [prompt, other, prompt],
                        [3, 6, 3], max_len=24)
    # 6 usable blocks: A leaves 2 cached prefix blocks, B occupies 3
    # live without evicting them, then C (same prompt as A) matches the
    # 2 cached blocks while only they are allocatable — the old check
    # counted them as free AND retained them, crashing on alloc()
    sch = Scheduler(cfg, params, slots=2, max_len=24, block_size=8,
                    num_blocks=7, chunk=8)
    sch.submit(Request(rid=0, prompt=prompt, max_new=3))
    sch.run()
    sch.submit(Request(rid=1, prompt=other, max_new=6))
    sch.submit(Request(rid=2, prompt=prompt, max_new=3))
    done = sch.run()
    assert {i: done[i] for i in refs} == refs


def test_scheduler_fused_epilogue_paged_decode(rng):
    """The §7 fused-epilogue decode chain over a paged cache (the
    apply_decoder_layer_fused paged branch): w4a8 + LUT + fuse_epilogue
    through the Scheduler must match the same deployment config through
    the dense Engine."""
    from repro.serve.engine import quantize_params
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, quant_mode="w4a8",
        use_lut_softmax=True, fuse_epilogue=True)
    params = quantize_params(api.init(jax.random.PRNGKey(5), cfg), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (9, 14)]
    news = [4, 4]
    refs = _engine_refs(cfg, params, prompts, news, max_len=32)
    done, _ = _run_sched(cfg, params, prompts, news, slots=2, max_len=32,
                         block_size=8, chunk=8)
    assert done == refs


def test_prefix_cache_shares_blocks_across_requests(rng):
    """Two requests with the same 16-token prompt, served sequentially:
    the second must retain the first's cached blocks instead of
    allocating fresh ones."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(4), cfg)
    prompt = rng.integers(1, cfg.vocab_size, size=16).tolist()
    refs = _engine_refs(cfg, params, [prompt, prompt], [4, 4], max_len=64)
    sch = Scheduler(cfg, params, slots=1, max_len=64, block_size=8,
                    num_blocks=12, chunk=8)
    sch.submit(Request(rid=0, prompt=prompt, max_new=4))
    done0 = sch.run()
    used_after_first = sch.pool.peak_in_use
    sch.submit(Request(rid=1, prompt=prompt, max_new=4))
    done1 = sch.run()
    assert done1[0] == refs[0] and done1[1] == refs[1]
    # request 2 reused the hashed prompt blocks: peak usage grew by at
    # most the private tail + decode blocks, not a full re-prefill
    assert sch.pool.peak_in_use <= used_after_first + 2
    assert done0[0] == refs[0]


# ---------------------------------------------------------------------------
# PR 6: kernelized chunk-prefill path through the Scheduler
# ---------------------------------------------------------------------------

def test_scheduler_scan_lowering_token_identical_to_oracle(rng, monkeypatch):
    """Satellite 6: the chunk step feeds the block table straight to
    ``ops.paged_flash_prefill``. Its opt-in O(written-prefix) scan
    lowering (REPRO_OPT_PAGEDFLASH=1) must produce token-identical greedy
    outputs vs the PR 5 materialized-gather path (REPRO_CHUNK_ORACLE=1)
    AND vs the dense Engine."""
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32,
                                                      num_layers=2)
    params = api.init(jax.random.PRNGKey(0), cfg)
    sysp = rng.integers(1, cfg.vocab_size, size=18).tolist()
    prompts = [sysp + rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (3, 21, 9)]
    news = [5, 6, 4]
    refs = _engine_refs(cfg, params, prompts, news, max_len=96)
    monkeypatch.setenv("REPRO_CHUNK_ORACLE", "1")
    oracle, _ = _run_sched(cfg, params, prompts, news, slots=3, max_len=96,
                           block_size=8, chunk=16)
    monkeypatch.delenv("REPRO_CHUNK_ORACLE")
    monkeypatch.setenv("REPRO_OPT_PAGEDFLASH", "1")
    scan, sch = _run_sched(cfg, params, prompts, news, slots=3, max_len=96,
                           block_size=8, chunk=16)
    assert scan == oracle == refs
    # the amortization report now carries the per-tick prefill launches
    amort = sch.stream_amortization_report()
    assert amort["prefill_launches"] >= len(prompts)
    assert amort["mean_prefill_launches"] >= 1.0


def test_prefill_eqn_count_kernel_residency(monkeypatch):
    """PR 6 acceptance: on the kernel path the chunked-prefill hot loop
    issues ZERO non-Pallas attention/matmul dispatches across dense /
    MoE / VLM — dense & VLM traces keep exactly one dot_general (the LM
    head, outside the layer loop; MoE adds only its non-quantized expert
    routing einsums) and the oracle arm's extra dispatches are exactly
    the 2 attention einsums (QK, PV) and the 2-per-pool densify gathers
    the kernel eliminates."""
    from repro.serve.engine import quantize_params
    for name, extra, inherent_dots in (
            ("llama2-7b", {}, 1),             # the LM head only
            ("dbrx-132b", {"capacity_factor": 8.0}, None),  # + expert mix
            ("qwen2-vl-2b", {}, 1)):
        cfg = get_config(name, smoke=True).replace(
            dtype=jnp.float32, quant_mode="w4a8", use_lut_softmax=True,
            **extra)
        params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
        ops.force_pallas(True)
        try:
            eng = Engine(cfg, params, max_len=64)
            kern = {p: eng.prefill_eqn_count(chunk=16, primitive=p)
                    for p in ("pallas_call", "dot_general", "gather")}
            monkeypatch.setenv("REPRO_CHUNK_ORACLE", "1")
            eng_o = Engine(cfg, params, max_len=64)
            orac = {p: eng_o.prefill_eqn_count(chunk=16, primitive=p)
                    for p in ("dot_general", "gather")}
            monkeypatch.delenv("REPRO_CHUNK_ORACLE")
        finally:
            ops.force_pallas(None)
        assert kern["pallas_call"] > 0, name
        if inherent_dots is not None:
            assert kern["dot_general"] == inherent_dots, (name, kern)
        # the kernel eliminates exactly the oracle's QK/PV einsums ...
        assert orac["dot_general"] - kern["dot_general"] == 2, (name, orac)
        # ... and its dense K/V materialization gathers (per pool, both
        # the table→flat-index and the pool-row gather): no dense KV on
        # the kernel path, prefix-cache hits stay paged
        assert orac["gather"] - kern["gather"] >= 2, (name, orac)
