"""Structured N:M weight sparsity (DESIGN.md §14): prune/compact/expand
round-trips, the sparse WS-OCS kernel family vs the dense-mask
reconstruction reference (f32 tolerance + bit-exact int accumulation),
untileable-shape error reporting, the quantize/prune params walk, and
end-to-end token identity of a 2:4-sparse checkpoint vs its dense-masked
equivalent through the Engine and the paged Scheduler.

Bit-exactness caveat (see ``ref.int_group_matmul_ref``): XLA contracts
the f32 scale-combine mul+add into an FMA below HLO, so eager and
compiled evaluations of the same chain differ by ~1 ulp. All bit-level
comparisons here are jit-vs-jit (the interpret-mode kernel is compiled),
where both sides share one contraction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import (QuantConfig, SparsityConfig, compact_nm,
                              expand_nm, mask_rank, nm_prune_mask,
                              pack_bitmask, parse_sparsity, quantize_weight,
                              sparse_ok, sparsify_weight, unpack_bitmask)
from repro.kernels import ref, sparse_matmul as sm
from repro.models import api
from repro.serve.engine import (Engine, ServeConfig, prune_params,
                                quantize_params)

SPECS = [SparsityConfig(2, 4, "col"), SparsityConfig(2, 4, "row"),
         SparsityConfig(1, 4, "col"), SparsityConfig(3, 8, "row")]


def _sw(rng, n, k, sp, mode="w4a8", group=16):
    w = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    qc = QuantConfig(mode, group)
    sw = sparsify_weight(w, qc, sp)
    wd = w * nm_prune_mask(w, sp).astype(w.dtype)
    qw = quantize_weight(wd, qc)
    return w, sw, qw


# ---------------------------------------------------------------------------
# config parsing / pruning invariants
# ---------------------------------------------------------------------------

def test_parse_sparsity():
    assert parse_sparsity("") is None
    assert parse_sparsity(None) is None
    sp = parse_sparsity("2:4")
    assert (sp.n, sp.m, sp.granularity) == (2, 4, "col")
    assert sp.key == "sp2of4"
    assert abs(sp.keep_frac - 0.5) < 1e-9
    sp = parse_sparsity("3:8:row")
    assert (sp.n, sp.m, sp.granularity) == (3, 8, "row")
    for bad in ("4:4", "0:4", "5:4", "x:y", "2:4:diag"):
        with pytest.raises(ValueError):
            parse_sparsity(bad)


@pytest.mark.parametrize("sp", SPECS, ids=lambda s: s.key + s.granularity)
def test_prune_mask_keeps_exactly_n_per_group(rng, sp):
    w = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
    mask = np.asarray(nm_prune_mask(w, sp))
    if sp.granularity == "col":
        per_group = mask.reshape(32 // sp.m, sp.m, 24).sum(axis=1)
        assert (per_group == sp.n).all()
    else:
        kept_rows = mask.all(axis=1)
        dropped = ~mask.any(axis=1)
        assert (kept_rows | dropped).all()      # whole rows only
        assert (kept_rows.reshape(-1, sp.m).sum(axis=1) == sp.n).all()
    # magnitude property: every kept |w| ≥ every dropped |w| within its
    # selection group
    a = np.abs(np.asarray(w))
    if sp.granularity == "col":
        g = a.reshape(-1, sp.m, 24)
        mg = mask.reshape(-1, sp.m, 24)
        kept_min = np.where(mg, g, np.inf).min(axis=1)
        drop_max = np.where(~mg, g, -np.inf).max(axis=1)
        assert (kept_min >= drop_max).all()
    else:
        s = a.sum(axis=1).reshape(-1, sp.m)
        mg = kept_rows.reshape(-1, sp.m)
        assert (np.where(mg, s, np.inf).min(axis=1)
                >= np.where(~mg, s, -np.inf).max(axis=1)).all()


def test_bitmask_roundtrip(rng):
    mask = jnp.asarray(rng.integers(0, 2, size=(40, 17)), bool)
    packed = pack_bitmask(mask)
    assert packed.dtype == jnp.uint8 and packed.shape == (5, 17)
    assert (np.asarray(unpack_bitmask(packed, 40)) == np.asarray(mask)).all()


@pytest.mark.parametrize("sp", SPECS, ids=lambda s: s.key + s.granularity)
def test_compact_expand_roundtrip(rng, sp):
    n_rows, k = 32, 12
    w = jnp.asarray(rng.standard_normal((n_rows, k)), jnp.float32)
    mask = nm_prune_mask(w, sp)
    q = jnp.asarray(rng.integers(-8, 8, size=(n_rows, k)), jnp.int8)
    qm = q * mask.astype(q.dtype)
    vals, idx = compact_nm(qm, mask, sp)
    assert vals.shape[0] == n_rows * sp.n // sp.m
    back = expand_nm(vals, idx, sp, n_rows)
    assert (np.asarray(back) == np.asarray(qm)).all()


def test_mask_rank_is_exclusive_cumsum():
    mask = jnp.asarray([[1, 0], [0, 1], [1, 1], [0, 0]], bool)
    r = np.asarray(mask_rank(mask, 4))
    assert (r[:, 0] == [0, 1, 1, 2]).all()
    assert (r[:, 1] == [0, 0, 1, 2]).all()


@pytest.mark.parametrize("sp", SPECS, ids=lambda s: s.key + s.granularity)
@pytest.mark.parametrize("mode", ["w4a8", "w8a8"])
def test_sparsify_matches_dense_masked_quantization(rng, sp, mode):
    """The §14 contract: compressed codes/scales are bit-identical to
    quantizing the dense-masked weight, so expand→dequantize reproduces
    the dense-masked checkpoint exactly."""
    w, sw, qw = _sw(rng, 32, 16, sp, mode)
    assert (np.asarray(sw.scale) == np.asarray(qw.scale)).all()
    exp = ref.sparse_expand_q_ref(sw.data, sw.idx, n=sp.n, m=sp.m,
                                  bits=sw.bits, n_rows=32)
    from repro.core.quant import unpack_int4
    dense_q = unpack_int4(qw.data, axis=0) if mode == "w4a8" else qw.data
    assert (np.asarray(exp) == np.asarray(dense_q)).all()
    assert (np.asarray(sw.dequantize()) == np.asarray(qw.dequantize())).all()


def test_sparse_ok_eligibility():
    col, row = SparsityConfig(2, 4, "col"), SparsityConfig(2, 4, "row")
    assert sparse_ok(32, col) and sparse_ok(32, row)
    assert not sparse_ok(30, col)        # 30 % 8 != 0 (bitmask bytes)
    assert not sparse_ok(18, col)
    assert not sparse_ok(18, row)        # 18 % 4 != 0
    assert sparse_ok(4, row)             # Nc = 2, even → nibble-packable
    assert sparse_ok(8, row)
    assert not sparse_ok(4, SparsityConfig(1, 4, "row"))  # Nc = 1, odd


# ---------------------------------------------------------------------------
# kernels vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp", SPECS, ids=lambda s: s.key + s.granularity)
@pytest.mark.parametrize("bm,bk", [(16, 48), (8, 24)])
def test_sparse_ws_ocs_matches_ref_f32(rng, sp, bm, bk):
    M, N, K = 16, 32, 48
    w, sw, qw = _sw(rng, N, K, sp)
    x = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    want = ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4)
    got = sm.sparse_ws_ocs_matmul(x, sw.data, sw.scale, sw.idx,
                                  n=sp.n, m=sp.m, bits=4, bm=bm, bk=bk,
                                  interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sp", SPECS[:2], ids=lambda s: s.granularity)
@pytest.mark.parametrize("mode", ["w4a8", "w8a8"])
def test_sparse_ws_ocs_int_accum_bit_exact(rng, sp, mode):
    M, N, K = 8, 32, 16
    w, sw, qw = _sw(rng, N, K, sp, mode)
    xq = jnp.asarray(rng.integers(-8, 8, size=(M, N)), jnp.int8)
    xs = jnp.asarray(rng.uniform(0.5, 2.0, size=(M, 1)), jnp.float32)
    got = sm.sparse_ws_ocs_matmul(xq, sw.data, sw.scale, sw.idx,
                                  n=sp.n, m=sp.m, bits=sw.bits, x_scale=xs,
                                  accum="int32", bm=M, bk=K, interpret=True)
    want = jax.jit(lambda: ref.sparse_ws_ocs_matmul_ref(
        xq, sw.data, sw.scale, sw.idx, n=sp.n, m=sp.m, bits=sw.bits,
        x_scale=xs, accum="int32"))()
    assert (np.asarray(got) == np.asarray(want)).all()


def test_row_skip_ref_int_matches_dense_mask_int(rng):
    """Dropped rows contribute exactly zero, so the compressed-skip
    lowering's INT32 partials equal the dense-mask reconstruction's
    partials bit for bit per scale group. The f32 scale-combine is only
    ~1-ulp close between the two lowerings (XLA contracts each chain's
    mul+add independently), which is why token identity is defined
    against the dense-mask default, not REPRO_OPT_SPARSESKIP."""
    from repro.core.quant import unpack_int4
    sp = SparsityConfig(2, 4, "row")
    M, N, K = 8, 32, 16
    w, sw, qw = _sw(rng, N, K, sp)
    xq = jnp.asarray(rng.integers(-8, 8, size=(M, N)), jnp.int8)
    xs = jnp.asarray(rng.uniform(0.5, 2.0, size=(M, 1)), jnp.float32)
    q_dense = ref.sparse_expand_q_ref(sw.data, sw.idx, n=2, m=4, bits=4,
                                      n_rows=N)
    vals = unpack_int4(sw.data, axis=0, n=N // 2)
    xc = jnp.take(xq, sw.idx, axis=1)
    G = sw.scale.shape[0]
    for gi in range(G):
        gs_d, gs_c = N // G, (N // 2) // G
        pd = xq[:, gi * gs_d:(gi + 1) * gs_d].astype(jnp.int32) \
            @ q_dense[gi * gs_d:(gi + 1) * gs_d].astype(jnp.int32)
        pc = xc[:, gi * gs_c:(gi + 1) * gs_c].astype(jnp.int32) \
            @ vals[gi * gs_c:(gi + 1) * gs_c].astype(jnp.int32)
        assert (np.asarray(pd) == np.asarray(pc)).all(), gi
    a = jax.jit(lambda: ref.sparse_skip_matmul_ref(
        xq, sw.data, sw.scale, sw.idx, n=2, m=4, bits=4, x_scale=xs,
        accum="int32"))()
    b = jax.jit(lambda: ref.sparse_ws_ocs_matmul_ref(
        xq, sw.data, sw.scale, sw.idx, n=2, m=4, bits=4, x_scale=xs,
        accum="int32"))()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sp", SPECS[:2], ids=lambda s: s.granularity)
def test_sparse_fused_full_epilogue_matches_ref(rng, sp):
    """norm → GEMM → SiLU·GLU → bias → residual → requant, sparse main
    AND sparse gate."""
    M, N, K = 16, 32, 32
    w, sw, _ = _sw(rng, N, K, sp)
    w2, sw2, _ = _sw(rng, N, K, sp)
    x = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    gamma = jnp.asarray(rng.standard_normal((N,)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((K,)), jnp.float32)
    res = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    kw = dict(n=sp.n, m=sp.m, bits=4, gamma=gamma, norm_group=16,
              act="silu", w2_data=sw2.data, w2_scale=sw2.scale,
              w2_idx=sw2.idx, bias=bias, residual=res)
    want = ref.sparse_fused_matmul_ref(x, sw.data, sw.scale, sw.idx, **kw)
    got = sm.sparse_fused_matmul(x, sw.data, sw.scale, sw.idx,
                                 bm=M, bk=16, interpret=True, **kw)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sp", SPECS[:2], ids=lambda s: s.granularity)
def test_sparse_fused_int_accum_bit_exact(rng, sp):
    M, N, K = 8, 32, 16
    w, sw, _ = _sw(rng, N, K, sp)
    w2, sw2, _ = _sw(rng, N, K, sp)
    xq = jnp.asarray(rng.integers(-8, 8, size=(M, N)), jnp.int8)
    xs = jnp.asarray(rng.uniform(0.5, 2.0, size=(M, 1)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((K,)), jnp.float32)
    kw = dict(n=sp.n, m=sp.m, bits=4, x_scale=xs, act="silu",
              w2_data=sw2.data, w2_scale=sw2.scale, w2_idx=sw2.idx,
              bias=bias, accum="int32")
    got = sm.sparse_fused_matmul(xq, sw.data, sw.scale, sw.idx,
                                 bm=M, bk=K, interpret=True, **kw)
    want = jax.jit(lambda: ref.sparse_fused_matmul_ref(
        xq, sw.data, sw.scale, sw.idx, **kw))()
    assert (np.asarray(got) == np.asarray(want)).all()


def test_sparse_fused_int_accum_rejects_gamma(rng):
    sp = SparsityConfig(2, 4, "col")
    w, sw, _ = _sw(rng, 32, 16, sp)
    x = jnp.asarray(rng.integers(-8, 8, size=(8, 32)), jnp.int8)
    g = jnp.ones((32,), jnp.float32)
    with pytest.raises(ValueError):
        ref.sparse_fused_matmul_ref(x, sw.data, sw.scale, sw.idx,
                                    n=2, m=4, gamma=g, accum="int32")
    with pytest.raises(ValueError):
        sm.sparse_fused_matmul(x, sw.data, sw.scale, sw.idx, n=2, m=4,
                               gamma=g, accum="int32", bm=8, bk=16,
                               interpret=True)


@pytest.mark.parametrize("sp", SPECS[:2], ids=lambda s: s.granularity)
@pytest.mark.parametrize("rcw", [True, False])
def test_sparse_rcw_matches_ref(rng, sp, rcw):
    M, N, K = 16, 32, 48
    w, sw, qw = _sw(rng, N, K, sp)
    x = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    want = ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4)
    got = sm.sparse_rcw_matmul(x, sw.data, sw.scale, sw.idx, n=sp.n,
                               m=sp.m, bits=4, bm=M, bk=16, rcw=rcw,
                               interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fn", ["ws", "fused", "rcw"])
def test_sparse_untileable_error_reports_shapes(rng, fn):
    sp = SparsityConfig(2, 4, "col")
    w, sw, _ = _sw(rng, 32, 48, sp)
    x = jnp.asarray(rng.standard_normal((10, 32)), jnp.float32)
    call = {
        "ws": lambda: sm.sparse_ws_ocs_matmul(
            x, sw.data, sw.scale, sw.idx, n=2, m=4, bm=4, bk=48,
            interpret=True),
        "fused": lambda: sm.sparse_fused_matmul(
            x, sw.data, sw.scale, sw.idx, n=2, m=4, bm=4, bk=48,
            interpret=True),
        "rcw": lambda: sm.sparse_rcw_matmul(
            x, sw.data, sw.scale, sw.idx, n=2, m=4, bm=4, bk=48,
            interpret=True),
    }[fn]
    with pytest.raises(ValueError) as ei:
        call()
    msg = str(ei.value)
    assert "(10, 32)" in msg and "bm=" in msg and "bk=" in msg, msg


# ---------------------------------------------------------------------------
# params walk + serving equivalence
# ---------------------------------------------------------------------------

def test_quantize_params_walk_sparse_leaves():
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", sparsity="2:4")
    params = api.init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg)
    keys = set()

    def walk(node):
        if isinstance(node, dict):
            if "q" in node and "scale" in node:
                keys.update(k for k in node if k.startswith("sp"))
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
    walk(qp)
    assert keys == {"sp2of4"}, keys
    # 3-D stacked (scanned) leaves carry a leading layer axis
    found3d = []

    def walk3(node, path=""):
        if isinstance(node, dict):
            if "sp2of4" in node and hasattr(node["sp2of4"], "ndim"):
                found3d.append(node["sp2of4"].ndim)
            for k, v in node.items():
                walk3(v, path + "/" + str(k))
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk3(v, path)
    walk3(qp)
    assert 3 in found3d      # scanned col metadata: (layers, N//8, K)


def test_bf16_and_dense_params_unchanged():
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, sparsity="2:4")       # quant_mode=bf16
    params = api.init(jax.random.PRNGKey(0), cfg)
    assert quantize_params(params, cfg) is params
    assert prune_params(params, cfg) is params
    dense_cfg = cfg.replace(quant_mode="w4a8", sparsity="")
    assert prune_params(params, dense_cfg) is params


@pytest.mark.parametrize("arch", ["llama2-7b", "dbrx-132b", "qwen2-vl-2b"])
@pytest.mark.parametrize("spec", ["2:4", "2:4:row"])
def test_engine_token_identity_sparse_vs_dense_masked(rng, arch, spec):
    """The acceptance contract: a sparse checkpoint serves token-
    identically to quantizing the dense-masked weights."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32,
                                               quant_mode="w4a8")
    params = api.init(jax.random.PRNGKey(0), cfg)
    scfg = cfg.replace(sparsity=spec)
    sp_params = quantize_params(params, scfg)
    dm_params = quantize_params(prune_params(params, scfg), cfg)
    toks = (np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab_size)
    extra = None
    if cfg.family == "vlm":
        extra = {"vision_embeds": np.zeros(
            (2, cfg.vision_patches, cfg.d_model), np.float32)}
    o1 = Engine(scfg, sp_params, max_len=64).generate(
        toks, ServeConfig(max_new_tokens=6), extra_batch=extra)
    o2 = Engine(cfg, dm_params, max_len=64).generate(
        toks, ServeConfig(max_new_tokens=6), extra_batch=extra)
    assert np.array_equal(o1, o2)


@pytest.mark.parametrize("arch,extra_cfg", [
    ("llama2-7b", {}),
    ("dbrx-132b", {"capacity_factor": 8.0}),
    ("qwen2-vl-2b", {}),
])
def test_paged_scheduler_token_identity_sparse(rng, arch, extra_cfg):
    """2:4-sparse vs dense-masked through the paged Scheduler (chunked
    prefill + paged decode) on dense / MoE / VLM."""
    from repro.serve.batching import Request
    from repro.serve.paged import Scheduler

    cfg = get_config(arch, smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", **extra_cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    scfg = cfg.replace(sparsity="2:4")
    sp_params = quantize_params(params, scfg)
    dm_params = quantize_params(prune_params(params, scfg), cfg)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 9)]

    def run(c, p):
        sch = Scheduler(c, p, slots=2, max_len=64, block_size=8, chunk=8)
        for i, pr in enumerate(prompts):
            sch.submit(Request(rid=i, prompt=pr, max_new=5))
        return sch.run()

    assert run(scfg, sp_params) == run(cfg, dm_params)


def test_fused_epilogue_token_identity_sparse():
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", fuse_epilogue=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = (np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab_size)
    for spec in ("2:4", "2:4:row"):
        scfg = cfg.replace(sparsity=spec)
        o1 = Engine(scfg, quantize_params(params, scfg), max_len=64) \
            .generate(toks, ServeConfig(max_new_tokens=6))
        o2 = Engine(cfg, quantize_params(prune_params(params, scfg), cfg),
                    max_len=64).generate(toks, ServeConfig(max_new_tokens=6))
        assert np.array_equal(o1, o2), spec


def test_sparseskip_dispatch_close(rng, monkeypatch):
    """REPRO_OPT_SPARSESKIP=1 switches the off-TPU row-granular lowering
    to the compressed-skip reference; logits must stay numerically close
    to the dense-mask reconstruction (same nonzero products, different
    summation order — platform round-off only)."""
    monkeypatch.setenv("REPRO_OPT_SPARSESKIP", "1")
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8")
    scfg = cfg.replace(sparsity="2:4:row")
    params = api.init(jax.random.PRNGKey(0), cfg)
    sp_params = quantize_params(params, scfg)
    dm_params = quantize_params(prune_params(params, scfg), cfg)
    toks = jnp.arange(8, dtype=jnp.int32).reshape(2, 4) % cfg.vocab_size
    batch = {"tokens": toks}
    l1, _ = api.prefill_step(sp_params, scfg, batch,
                             api.init_cache(scfg, 2, 16))
    l2, _ = api.prefill_step(dm_params, cfg, batch,
                             api.init_cache(cfg, 2, 16))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# perf model rows
# ---------------------------------------------------------------------------

def test_perf_model_sparsity_rows():
    from repro.sim import perf_model as pm
    f_col = pm.sparse_weight_factor(2, 4, "col", bits=4)
    assert abs(f_col - 0.75) < 1e-9          # 3 bits/elem vs 4
    f_row = pm.sparse_weight_factor(2, 4, "row", bits=4)
    assert 0.5 < f_row < 0.51                # index overhead ≈ negligible
    for gran in ("col", "row"):
        r = pm.sparsity_report(2, 4, gran)
        assert r["decode_speedup"] > 1.0
        assert r["prefill_speedup"] > 1.0
        assert 0.0 < r["update_reduction"] < 1.0
        assert r["sparse_prefill_dram_mb"] < r["dense_prefill_dram_mb"]
    # denser spec → smaller saving
    assert pm.sparsity_report(3, 4, "col")["decode_speedup"] \
        < pm.sparsity_report(1, 4, "col")["decode_speedup"]
