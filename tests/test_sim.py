"""The analytical chip model must reproduce the paper's headline numbers
(Table II, Fig 8, Fig 9) within the documented residuals."""
import pytest

from repro.sim import perf_model as pm
from repro.sim.chip import RCWCIM


def test_peak_tops_matches_table2():
    assert abs(RCWCIM.peak_tops - 3.28) < 0.01


def test_fig8a_dram_reduction():
    r = pm.fig8a_dram_reduction()
    assert abs(r["reduction"] - 0.516) < 0.02          # fitted tiles: 50.4%


def test_fig8b_update_reduction():
    r = pm.fig8b_update_reduction()
    assert abs(r["reduction"] - 0.876) < 0.005


def test_fig9a_prefill():
    r = pm.fig9a_prefill_reduction()
    assert abs(r["reduction"] - 0.4976) < 0.005
    assert abs(r["per_token_ms"] - 4.2) < 0.05


def test_fig9b_decode_chain():
    r = pm.fig9b_decode_reductions()
    assert abs(r["rcw_reduction"] - 0.2159) < 0.012    # −0.8pp residual
    assert abs(r["fusion_reduction"] - 0.6917) < 0.005
    assert abs(r["total_reduction"] - 0.7583) < 0.005
    assert abs(r["tokens_per_s"] - 26.87) < 0.05


def test_table2_summary():
    t = pm.table2_summary()
    assert abs(t["prefill_per_token_ms"] - 4.2) < 0.05
    assert abs(t["decode_tokens_per_s"] - 26.87) < 0.05


def test_decode_monotonicity():
    """Each mechanism must strictly help, at any context length."""
    for ctx in (256, 1024, 4096):
        base = pm.decode_latency(rcw=False, fusion=False, ctx=ctx)
        rcw = pm.decode_latency(rcw=True, fusion=False, ctx=ctx)
        both = pm.decode_latency(rcw=True, fusion=True, ctx=ctx)
        assert both < rcw < base


def test_prefill_dataflow_ordering():
    from repro.core.dataflow import Dataflow
    ocs = pm.prefill_latency(Dataflow.WS_OCS, rcw=True)
    ws_os = pm.prefill_latency(Dataflow.WS_OS, rcw=False)
    is_os = pm.prefill_latency(Dataflow.IS_OS, rcw=False)
    assert ocs < ws_os
    assert ocs < is_os
