"""Fused-epilogue WS-OCS kernels and the single-dispatch attention
decode kernel vs their unfused compositions (ref.py), plus the engine
dispatch-count acceptance check (ISSUE 3 / DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig, quantize_weight
from repro.kernels import ops, ref
from repro.kernels.attention_decode import attention_decode
from repro.kernels.ws_ocs_matmul import fused_matmul


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _qw(rng, n, k, bits=4, group=64):
    mode = "w4a8" if bits == 4 else "w8a8"
    w = rng.standard_normal((n, k)).astype(np.float32)
    return quantize_weight(jnp.asarray(w), QuantConfig(mode, group))


def _assert_close(got, want, tol=1e-5):
    """|got − want| ≤ tol relative to the output magnitude (the 1e-5
    acceptance bound; GLU products reach O(10²-10³) so a raw atol would
    test fp32 round-off, not the kernel)."""
    scale = max(1.0, float(np.abs(np.asarray(want)).max()))
    err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
    assert err <= tol * scale, (err, scale)


# ---------------------------------------------------------------------------
# fused matmul epilogues
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("M,N,K", [(8, 256, 128), (16, 128, 64)])
def test_fused_epilogue_bias_residual_silu(rng, M, N, K, bits):
    qw = _qw(rng, N, K, bits)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    bias = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    res = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    kw = dict(bits=bits, act="silu", bias=bias, residual=res)
    got = fused_matmul(x, qw.data, qw.scale, bm=min(8, M), bk=min(64, K),
                       interpret=True, **kw)
    want = ref.fused_matmul_ref(x, qw.data, qw.scale, **kw)
    _assert_close(got, want)


@pytest.mark.parametrize("bits", [4, 8])
def test_fused_rmsnorm_prologue_glu(rng, bits):
    """Group-RMSNorm prologue + SwiGLU dual-GEMM gate in one kernel."""
    M, N, K = 8, 256, 128
    qw, qw2 = _qw(rng, N, K, bits), _qw(rng, N, K, bits)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    gamma = jnp.asarray(rng.standard_normal(N).astype(np.float32))
    kw = dict(bits=bits, gamma=gamma, norm_group=64, act="silu",
              w2_data=qw2.data, w2_scale=qw2.scale)
    got = fused_matmul(x, qw.data, qw.scale, bm=4, bk=64, interpret=True,
                       **kw)
    want = ref.fused_matmul_ref(x, qw.data, qw.scale, **kw)
    _assert_close(got, want)


def test_fused_gelu_bias(rng):
    M, N, K = 8, 128, 64
    qw = _qw(rng, N, K)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    bias = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    gamma = jnp.ones(N)
    kw = dict(bits=4, gamma=gamma, norm_group=128, act="gelu", bias=bias)
    got = fused_matmul(x, qw.data, qw.scale, bm=8, bk=64, interpret=True,
                       **kw)
    want = ref.fused_matmul_ref(x, qw.data, qw.scale, **kw)
    _assert_close(got, want)


@pytest.mark.parametrize("bits", [4, 8])
def test_fused_requant_int8_epilogue(rng, bits):
    """Activation re-quantization to int8 for the next W4A8 GEMM happens
    inside the kernel and matches the two-pass reference bit-for-bit."""
    M, N, K = 16, 128, 128
    qw = _qw(rng, N, K, bits)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    osc = jnp.asarray(
        (np.abs(rng.standard_normal((M, 1))) + 0.5).astype(np.float32))
    got = fused_matmul(x, qw.data, qw.scale, bits=bits, out_scale=osc,
                       bm=8, bk=64, interpret=True)
    want = ref.fused_matmul_ref(x, qw.data, qw.scale, bits=bits,
                                out_scale=osc)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_x_scale_int8_activations(rng):
    """int8 activations with per-row scale through the fused path."""
    from repro.core.quant import quantize_int8
    M, N, K = 8, 128, 64
    qw = _qw(rng, N, K)
    xf = rng.standard_normal((M, N)).astype(np.float32)
    xq, xs = quantize_int8(jnp.asarray(xf), axis=-1)
    kw = dict(bits=4, x_scale=xs, act="silu")
    got = fused_matmul(xq, qw.data, qw.scale, bm=8, bk=64, interpret=True,
                       **kw)
    want = ref.fused_matmul_ref(xq, qw.data, qw.scale, **kw)
    _assert_close(got, want)


def test_plain_fused_matches_unfused_kernel(rng):
    """No epilogue requested → identical to the plain WS-OCS kernel."""
    from repro.kernels.ws_ocs_matmul import ws_ocs_matmul
    M, N, K = 16, 128, 64
    qw = _qw(rng, N, K)
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    got = fused_matmul(x, qw.data, qw.scale, bits=4, bm=8, bk=32,
                       interpret=True)
    want = ws_ocs_matmul(x, qw.data, qw.scale, bits=4, bm=8, bk=32,
                         interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# fused attention decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_lut", [True, False])
@pytest.mark.parametrize("B,H,Hkv,S,D", [(2, 8, 2, 256, 32),
                                         (1, 4, 4, 128, 64)])
def test_attention_decode_kernel_vs_ref(rng, B, H, Hkv, S, D, use_lut):
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, S + 1, size=(B,)), jnp.int32)
    got = attention_decode(q, k, v, lens, group_size=64, use_lut=use_lut,
                           block_k=128, interpret=True)
    want = ref.attention_decode_ref(q, k, v, lens, group_size=64,
                                    use_lut=use_lut)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_decode_window(rng):
    B, H, Hkv, S, D = 2, 4, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    lens = jnp.asarray([200, 77], jnp.int32)
    got = attention_decode(q, k, v, lens, group_size=64, use_lut=True,
                           window=64, block_k=64, interpret=True)
    want = ref.attention_decode_ref(q, k, v, lens, group_size=64,
                                    use_lut=True, window=64)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_decode_matches_exact_softmax(rng):
    """With exact exp and full-length prefix the kernel equals plain
    softmax attention over the cache."""
    B, H, S, D = 1, 4, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    lens = jnp.full((B,), S, jnp.int32)
    got = attention_decode(q, k, v, lens, group_size=64, use_lut=False,
                           interpret=True)
    logits = jnp.einsum("bhd,bshd->bhs", q, k) * D ** -0.5
    probs = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bhs,bshd->bhd", probs, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# engine-level: fused decode chain ≡ unfused, with fewer dispatches
# ---------------------------------------------------------------------------

def _smoke_engine(fused: bool):
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.engine import Engine, quantize_params
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", use_lut_softmax=True,
        fuse_epilogue=fused)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, quantize_params(params, cfg), max_len=64)


def test_fused_decode_path_matches_unfused_end_to_end():
    from repro.serve.engine import ServeConfig
    toks = np.arange(8, dtype=np.int32).reshape(2, 4) + 3
    sc = ServeConfig(max_new_tokens=6)
    out_u = _smoke_engine(False).generate(toks, sc)
    out_f = _smoke_engine(True).generate(toks, sc)
    np.testing.assert_array_equal(out_u, out_f)


def test_fused_decode_fewer_dispatches():
    """Acceptance: the fused decode step issues measurably fewer op
    dispatches (jaxpr eqns) and fewer kernel launches (pallas_call)."""
    ops.force_pallas(True)
    try:
        eng_u, eng_f = _smoke_engine(False), _smoke_engine(True)
        eq_u, eq_f = eng_u.decode_eqn_count(), eng_f.decode_eqn_count()
        pl_u = eng_u.decode_eqn_count(primitive="pallas_call")
        pl_f = eng_f.decode_eqn_count(primitive="pallas_call")
    finally:
        ops.force_pallas(None)
    assert eq_f < eq_u, (eq_f, eq_u)
    assert pl_f < pl_u, (pl_f, pl_u)
