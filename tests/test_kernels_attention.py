"""Flash-attention kernel vs the exact oracle: causal, windowed (local),
GQA head sharing, cross-attention, LUT-exp mode, dtype sweep, and the
PR 6 offset-causal mode (per-batch absolute ``q_offset`` for chunked
prefill, DESIGN.md §11)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _qkv(rng, B, H, Hkv, Sq, Sk, D, dtype=np.float32):
    q = rng.standard_normal((B, H, Sq, D)).astype(dtype)
    k = rng.standard_normal((B, Hkv, Sk, D)).astype(dtype)
    v = rng.standard_normal((B, Hkv, Sk, D)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 2, 2, 64, 32), (2, 4, 2, 128, 64), (1, 8, 1, 64, 32),
    (2, 6, 2, 96, 32),
])
def test_causal_flash_vs_ref(rng, B, H, Hkv, S, D):
    q, k, v = _qkv(rng, B, H, Hkv, S, S, D)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [16, 48, 200])
def test_local_window_flash(rng, window):
    q, k, v = _qkv(rng, 2, 4, 2, 128, 128, 32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cross_attention_flash(rng):
    q, k, v = _qkv(rng, 2, 4, 4, 32, 96, 32)
    got = flash_attention(q, k, v, causal=False, block_q=16, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_lut_mode_close_to_exact(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 64, 64, 32)
    got = flash_attention(q, k, v, causal=True, use_lut=True,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(got - want).max()) < 2e-2


def test_bf16_inputs(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 64, 64, 32)
    got = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), causal=True,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(got.astype(jnp.float32) - want).max()) < 5e-2


def test_block_size_invariance(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 128, 128, 32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=64,
                        interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=16,
                        interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Offset-causal mode (chunked prefill, DESIGN.md §11)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offs", [[0, 32], [17, 96 - 32], [5, 5]])
def test_offset_causal_flash_vs_ref(rng, offs):
    """Per-batch absolute query offsets: queries at q_offset[b]+i over a
    longer written prefix, masked offset-causally."""
    q, k, v = _qkv(rng, 2, 4, 2, 32, 96, 32)
    off = jnp.asarray(offs, jnp.int32)
    got = flash_attention(q, k, v, causal=True, q_offset=off,
                          block_q=16, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_offset_causal_composes_with_window(rng):
    q, k, v = _qkv(rng, 2, 4, 2, 32, 128, 32)
    off = jnp.asarray([40, 8], jnp.int32)
    got = flash_attention(q, k, v, causal=True, window=24, q_offset=off,
                          block_q=16, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=24, q_offset=off)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_offset_causal_lut_close_to_exact(rng):
    """LUT mode under the flash running rescale agrees with the exact
    oracle only to LUT tolerance (DESIGN.md §11)."""
    q, k, v = _qkv(rng, 1, 2, 2, 32, 64, 32)
    off = jnp.asarray([20], jnp.int32)
    got = flash_attention(q, k, v, causal=True, use_lut=True, q_offset=off,
                          block_q=16, block_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    assert float(jnp.abs(got - want).max()) < 2e-2


def test_offset_block_size_invariance(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 64, 128, 32)
    off = jnp.asarray([30], jnp.int32)
    a = flash_attention(q, k, v, causal=True, q_offset=off,
                        block_q=16, block_k=64, interpret=True)
    b = flash_attention(q, k, v, causal=True, q_offset=off,
                        block_q=64, block_k=16, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_offset_default_equals_trailing_queries(rng):
    """q_offset = Sk - Sq is the legacy rectangular-causal case: the
    explicit offset must reproduce the default path bit-for-bit (the
    wrapper feeds the same off operand either way)."""
    q, k, v = _qkv(rng, 2, 4, 2, 32, 96, 32)
    off = jnp.full((2,), 96 - 32, jnp.int32)
    a = flash_attention(q, k, v, causal=True, q_offset=off,
                        block_q=32, block_k=32, interpret=True)
    b = flash_attention(q, k, v, causal=True,
                        block_q=32, block_k=32, interpret=True)
    assert (np.asarray(a) == np.asarray(b)).all()
