"""Flash-attention kernel vs the exact oracle: causal, windowed (local),
GQA head sharing, cross-attention, LUT-exp mode, dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _qkv(rng, B, H, Hkv, Sq, Sk, D, dtype=np.float32):
    q = rng.standard_normal((B, H, Sq, D)).astype(dtype)
    k = rng.standard_normal((B, Hkv, Sk, D)).astype(dtype)
    v = rng.standard_normal((B, Hkv, Sk, D)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 2, 2, 64, 32), (2, 4, 2, 128, 64), (1, 8, 1, 64, 32),
    (2, 6, 2, 96, 32),
])
def test_causal_flash_vs_ref(rng, B, H, Hkv, S, D):
    q, k, v = _qkv(rng, B, H, Hkv, S, S, D)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [16, 48, 200])
def test_local_window_flash(rng, window):
    q, k, v = _qkv(rng, 2, 4, 2, 128, 128, 32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cross_attention_flash(rng):
    q, k, v = _qkv(rng, 2, 4, 4, 32, 96, 32)
    got = flash_attention(q, k, v, causal=False, block_q=16, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_lut_mode_close_to_exact(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 64, 64, 32)
    got = flash_attention(q, k, v, causal=True, use_lut=True,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(got - want).max()) < 2e-2


def test_bf16_inputs(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 64, 64, 32)
    got = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), causal=True,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(got.astype(jnp.float32) - want).max()) < 5e-2


def test_block_size_invariance(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 128, 128, 32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=64,
                        interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=16,
                        interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
