"""Fused selective-scan kernel vs oracle: shape sweep, block-size
invariance, state carry across calls, and equivalence with the
linear_recurrence formulation the model previously used."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import selective_scan, selective_scan_ref
from repro.models.scan_utils import linear_recurrence


def _inputs(rng, B, S, D, N):
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, D))).astype(np.float32) * 0.1)
    xs = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    al = jnp.asarray(rng.standard_normal((D, N)).astype(np.float32) * 0.3)
    h0 = jnp.asarray(rng.standard_normal((B, D, N)).astype(np.float32) * 0.5)
    return dt, xs, bm, cm, al, h0


@pytest.mark.parametrize("B,S,D,N", [(1, 64, 32, 4), (2, 128, 64, 8),
                                     (2, 64, 128, 16)])
def test_kernel_matches_ref(rng, B, S, D, N):
    args = _inputs(rng, B, S, D, N)
    y_ref, h_ref = selective_scan_ref(*args)
    y, h = selective_scan(*args, block_s=32, block_d=32, interpret=True)
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)


def test_block_size_invariance(rng):
    args = _inputs(rng, 2, 128, 64, 8)
    outs = [selective_scan(*args, block_s=bs, block_d=bd, interpret=True)
            for bs, bd in [(16, 16), (64, 64), (128, 32)]]
    for y, h in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(h, outs[0][1], rtol=1e-5, atol=1e-5)


def test_state_carry_composes(rng):
    """scan(S) == scan(S/2) ∘ scan(S/2) through the carried state."""
    dt, xs, bm, cm, al, h0 = _inputs(rng, 1, 64, 32, 4)
    y_full, h_full = selective_scan_ref(dt, xs, bm, cm, al, h0)
    y1, h1 = selective_scan_ref(dt[:, :32], xs[:, :32], bm[:, :32],
                                cm[:, :32], al, h0)
    y2, h2 = selective_scan_ref(dt[:, 32:], xs[:, 32:], bm[:, 32:],
                                cm[:, 32:], al, h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h2, h_full, rtol=1e-5, atol=1e-5)


def test_matches_linear_recurrence_form(rng):
    dt, xs, bm, cm, al, h0 = _inputs(rng, 2, 64, 32, 4)
    A = -jnp.exp(al)
    a = jnp.exp(dt[..., None] * A)
    b = (dt * xs)[..., None] * bm[:, :, None, :]
    hs, h_last = linear_recurrence(a, b, h0, chunk=16)
    y_lr = jnp.einsum("bsdn,bsn->bsd", hs, cm)
    y, h = selective_scan_ref(dt, xs, bm, cm, al, h0)
    np.testing.assert_allclose(y, y_lr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h, h_last, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused elementwise linear-recurrence kernel (RG-LRU)
# ---------------------------------------------------------------------------

from repro.kernels.linear_recurrence import linear_recurrence_kernel


@pytest.mark.parametrize("B,S,D,bs,bd", [(1, 64, 32, 16, 16),
                                         (2, 96, 64, 32, 32),
                                         (2, 128, 128, 128, 64)])
def test_linear_recurrence_kernel_vs_chunked_scan(rng, B, S, D, bs, bd):
    a = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, D)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))
    hs_ref, hl_ref = linear_recurrence(a, b, h0, chunk=16)
    hs, hl = linear_recurrence_kernel(a, b, h0, block_s=bs, block_d=bd,
                                      interpret=True)
    np.testing.assert_allclose(hs, hs_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(hl, hl_ref, rtol=1e-5, atol=1e-6)


def test_rglru_model_uses_kernel_path(rng):
    """recurrentgemma forward is identical through the kernel dispatch."""
    import jax
    from repro.configs import get_config
    from repro.kernels import ops
    from repro.models import api

    cfg = get_config("recurrentgemma-2b", smoke=True).replace(
        dtype=jnp.float32, remat=False)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    base = api.forward(params, cfg, batch)
    ops.force_pallas(True)
    try:
        via_kernel = api.forward(params, cfg, batch)
    finally:
        ops.force_pallas(None)
    np.testing.assert_allclose(via_kernel, base, rtol=1e-4, atol=1e-3)
