"""Trainer + serving integration: loss decreases, checkpoint/restart
resumes bit-exactly, rollback-on-failure works, the serving engine
generates with both bf16 and W4A8 (WS-OCS kernel path) weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serve.engine import Engine, ServeConfig, quantize_params
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def _tiny_cfg():
    return get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)


def _mk_trainer(tmp_path=None, steps=60, accum=1):
    cfg = _tiny_cfg()
    mesh = make_host_mesh()
    dc = DataConfig(seed=7, batch_size=4, seq_len=32,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=steps, log_every=10, ckpt_every=20,
                     ckpt_dir=str(tmp_path) if tmp_path else None,
                     grad_accum=accum)
    oc = OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    return Trainer(cfg, mesh, dc, tc, oc)


def test_loss_decreases():
    tr = _mk_trainer(steps=150)
    losses = []
    tr.run(on_metrics=lambda s, m: losses.append(m["loss"]))
    assert len(losses) >= 10
    # clear downward trend (the synthetic stream has a high entropy
    # floor, so require a robust absolute drop rather than a ratio)
    assert min(losses[-3:]) < losses[0] - 0.4, losses


def test_checkpoint_restart_bit_exact(tmp_path):
    tr1 = _mk_trainer(tmp_path / "ck", steps=40)
    tr1.run()                              # ckpts at 20, 40
    p40 = jax.device_get(tr1.params)

    # fresh trainer resumes from step 40 checkpoint and matches a
    # continuous run step-for-step (step-keyed data stream)
    tr2 = _mk_trainer(tmp_path / "ck", steps=40)
    assert tr2.step == 40
    tr1.run(steps=10)
    tr2.run(steps=10)
    a = jax.tree.leaves(jax.device_get(tr1.params))
    b = jax.tree.leaves(jax.device_get(tr2.params))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    del p40


def test_rollback_on_persistent_failure(tmp_path):
    tr = _mk_trainer(tmp_path / "ck", steps=20)
    tr.run()                               # ckpt at 20
    step_before = tr.step
    # inject a persistently failing step fn; trainer must roll back to
    # the checkpoint instead of crashing
    calls = {"n": 0}
    orig = tr._step_fn

    def flaky(params, opt, batch):
        calls["n"] += 1
        if calls["n"] <= 3:
            raise RuntimeError("simulated device failure")
        return orig(params, opt, batch)

    tr._step_fn = flaky
    tr.run(steps=2)
    assert tr.step == step_before + 2
    assert calls["n"] > 3


def test_grad_accum_matches_large_batch():
    """accum=2 over batch 8 ≈ accum=1 over the same batch (same tokens)."""
    cfg = _tiny_cfg()
    mesh = make_host_mesh()
    dc = DataConfig(seed=3, batch_size=8, seq_len=16,
                    vocab_size=cfg.vocab_size)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    t1 = Trainer(cfg, mesh, dc, TrainConfig(total_steps=1, grad_accum=1), oc)
    t2 = Trainer(cfg, mesh, dc, TrainConfig(total_steps=1, grad_accum=2), oc)
    t1.run(steps=1)
    t2.run(steps=1)
    a = jax.tree.leaves(jax.device_get(t1.params))
    b = jax.tree.leaves(jax.device_get(t2.params))
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5)


def test_elastic_restore_different_mesh(tmp_path):
    """Checkpoint saved under one mesh restores onto another (elastic)."""
    tr = _mk_trainer(tmp_path / "ck", steps=20)
    tr.run()
    cfg = _tiny_cfg()
    mesh2 = make_host_mesh(model=1, data=1)
    dc = DataConfig(seed=7, batch_size=4, seq_len=32,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=20, ckpt_dir=str(tmp_path / "ck"))
    tr2 = Trainer(cfg, mesh2, dc, tc, OptConfig(lr=3e-3))
    assert tr2.step == 20
    a = jax.tree.leaves(jax.device_get(tr.params))
    b = jax.tree.leaves(jax.device_get(tr2.params))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def test_engine_generates():
    cfg = _tiny_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=64)
    toks = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    out = eng.generate(toks, ServeConfig(max_new_tokens=8))
    assert out.shape == (2, 14)
    assert np.all(out[:, :6] == toks)


def test_quantized_serving_close_to_fp():
    """W4A8 WS-OCS serving path tracks the fp32 model (greedy tokens may
    differ on an untrained model; logits must stay close)."""
    cfg = _tiny_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qcfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True)
    qparams = quantize_params(params, qcfg)

    toks = jnp.arange(8, dtype=jnp.int32).reshape(1, 8) % cfg.vocab_size
    batch = {"tokens": toks}
    cache_f = api.init_cache(cfg, 1, 16)
    cache_q = api.init_cache(qcfg, 1, 16)
    lf, _ = api.prefill_step(params, cfg, batch, cache_f)
    lq, _ = api.prefill_step(qparams, qcfg, batch, cache_q)
    # every linear layer carries INT4 grouped-quant noise (random-init
    # weights are the worst case); the model-level check is that the
    # quantized logits track the fp logits strongly
    a = np.asarray(lf).ravel()
    b = np.asarray(lq).ravel()
    corr = float(np.corrcoef(a, b)[0, 1])
    assert corr > 0.95, corr
    rel = float(jnp.abs(lf - lq).max() / (jnp.abs(lf).max() + 1e-9))
    assert rel < 0.5, rel


def test_quantized_engine_end_to_end():
    cfg = _tiny_cfg().replace(quant_mode="w4a8", use_lut_softmax=True)
    params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
    eng = Engine(cfg, params, max_len=32)
    toks = np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab_size
    out = eng.generate(toks, ServeConfig(max_new_tokens=4))
    assert out.shape == (2, 8)
