"""Hypothesis property tests on the system's invariants: quantization
round-trips, dataflow access-count algebra (Table I), RCW pipeline
bounds, LUT softmax behavior, and the offset-causal flash kernel vs the
golden ``ref.attention_ref(q_offset=)`` oracle (DESIGN.md §11)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep; "
                    "pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import fusion
from repro.core.dataflow import (Dataflow, TileConfig, access_counts,
                                 simulate_access)
from repro.core.quant import (QuantConfig, pack_int4, quantize_int8,
                              quantize_weight, unpack_int4)
from repro.core.rcw import latency_rcw, latency_serial, latency_uniform, RCWStage
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

S = settings(max_examples=25, deadline=None)


@S
@given(st.integers(2, 64), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_pack_unpack_int4_roundtrip(n2, k, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(2 * n2, k)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q), axis=0)
    assert packed.shape == (n2, k)
    out = np.asarray(unpack_int4(packed, axis=0))
    np.testing.assert_array_equal(out, q)


@S
@given(st.integers(1, 65), st.integers(1, 33), st.integers(0, 1),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_int4_odd_lengths(n, k, axis, seed):
    """Odd packed-axis lengths zero-pad to a nibble boundary; the ``n=``
    trim on unpack restores the exact original (both axes)."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(n, k)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q), axis=axis)
    dim = (n, k)[axis]
    assert packed.shape[axis] == (dim + 1) // 2
    out = np.asarray(unpack_int4(packed, axis=axis, n=dim))
    np.testing.assert_array_equal(out, q)


@S
@given(st.integers(1, 64), st.integers(1, 33), st.integers(0, 2**31 - 1))
def test_pack_unpack_bitmask_roundtrip(n8, k, seed):
    from repro.core.quant import pack_bitmask, unpack_bitmask
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 2, size=(8 * n8, k)).astype(bool)
    packed = pack_bitmask(jnp.asarray(mask))
    assert packed.shape == (n8, k) and packed.dtype == jnp.uint8
    out = np.asarray(unpack_bitmask(packed, 8 * n8))
    np.testing.assert_array_equal(out, mask)


@S
@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_int8_quant_error_bound(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, cols)).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(x), axis=-1)
    back = np.asarray(q, np.float32) * np.asarray(scale)
    # symmetric int8: error ≤ scale/2 per element
    assert np.all(np.abs(back - x) <= np.asarray(scale) / 2 + 1e-7)


@S
@given(st.sampled_from([32, 64, 128]), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_int4_weight_quant_error_bound(group, kcols, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((group * 2, kcols * 8)).astype(np.float32)
    qw = quantize_weight(jnp.asarray(w), QuantConfig("w4a8", group))
    back = np.asarray(qw.dequantize())
    scale = np.repeat(np.asarray(qw.scale), group, axis=0)
    assert np.all(np.abs(back - w) <= scale / 2 + 1e-6)


_tile = st.integers(1, 6)


@S
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8),
       _tile, _tile, _tile)
def test_dataflow_sim_matches_table1(mm, nn, kk, tm, tn, tk):
    """The instrumented loop-nest walk reproduces the Table-I formulas.
    (WS-OCS input differs by exactly the first-tile fill m·N, which
    Table I omits — asserted exactly.)"""
    M, N, K = mm * tm, nn * tn, kk * tk
    tc = TileConfig(M=M, N=N, K=K, m=tm, n=tn, k=tk)
    for df in Dataflow:
        f = access_counts(df, tc)
        s = simulate_access(df, tc)
        if df == Dataflow.WS_OCS:
            assert s["input"] == f["input"] + tc.m * tc.N
            for key in ("weight", "output", "cim_update"):
                assert s[key] == f[key]
        else:
            assert s == f


@S
@given(st.integers(2, 16), st.integers(2, 16), st.integers(2, 16),
       _tile, _tile, _tile)
def test_ws_ocs_dominates(mm, nn, kk, tm, tn, tk):
    """WS-OCS never does more CIM updates than WS-OS/IS-OS and never more
    weight DRAM reads than IS variants (the paper's Table-I ordering)."""
    tc = TileConfig(M=mm * tm, N=nn * tn, K=kk * tk, m=tm, n=tn, k=tk)
    ocs = access_counts(Dataflow.WS_OCS, tc)
    ws_os = access_counts(Dataflow.WS_OS, tc)
    is_os = access_counts(Dataflow.IS_OS, tc)
    assert ocs["cim_update"] <= ws_os["cim_update"]
    assert ocs["cim_update"] <= is_os["cim_update"]
    assert ocs["weight"] <= is_os["weight"]
    assert ocs["output"] <= ws_os["output"]
    assert ocs["input"] <= access_counts(Dataflow.WS, tc)["input"]


@S
@given(st.integers(1, 50), st.floats(0.01, 10.0), st.floats(0.01, 10.0))
def test_rcw_latency_bounds(n, fill, compute):
    """RCW latency ∈ [max-bound, serial]: never worse than serial, never
    better than the critical path (all fills + last compute, or first
    fill + all computes)."""
    serial = latency_uniform(n, fill, compute, rcw=False)
    rcw = latency_uniform(n, fill, compute, rcw=True)
    lower = max(n * fill + compute, fill + n * compute)
    assert rcw <= serial + 1e-9
    assert rcw >= lower - 1e-6


@S
@given(st.lists(st.floats(0.01, 5.0), min_size=1, max_size=20),
       st.lists(st.floats(0.01, 5.0), min_size=1, max_size=20))
def test_rcw_nonuniform_consistency(fills, computes):
    n = min(len(fills), len(computes))
    stages = [RCWStage(fills[i], computes[i]) for i in range(n)]
    assert latency_rcw(stages) <= latency_serial(stages) + 1e-9


@settings(max_examples=12, deadline=None)    # interpret-mode kernel runs
@given(st.integers(0, 2**31 - 1),            # data + per-batch offsets
       st.sampled_from([(16, 32), (16, 64), (32, 64)]),   # (Sq=C, Sk)
       st.sampled_from([None, 12, 40]),      # sliding-window half-width
       st.booleans())                        # LUT vs exact exp
def test_offset_causal_flash_matches_oracle(seed, shape, window, use_lut):
    """Satellite sweep: q_offset × sliding-window × softmax mode. The
    offset-causal flash kernel must reproduce the golden materialized
    oracle ``ref.attention_ref(q_offset=)`` — to fp32 round-off in
    exact-exp mode, to LUT tolerance under the LUT running rescale
    (DESIGN.md §11)."""
    C, Sk = shape
    B, H, Hkv, D = 2, 4, 2, 32
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, C, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)).astype(np.float32))
    off = jnp.asarray(rng.integers(0, Sk - C + 1, size=B), jnp.int32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          use_lut=use_lut, q_offset=off,
                          block_q=16, block_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window,
                             q_offset=off)
    err = float(jnp.abs(got - want).max())
    assert err < (2e-2 if use_lut else 1e-5)


@S
@given(st.integers(1, 4), st.integers(2, 6), st.integers(0, 2**31 - 1),
       st.sampled_from([16, 32, 64]))
def test_group_softmax_is_distribution(rows, groups, seed, g):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, groups * g)).astype(np.float32) * 6
    out = np.asarray(fusion.group_softmax(jnp.asarray(x), g, use_lut=True))
    assert np.all(out >= 0)
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
    # order preserved: argmax of softmax == argmax of logits
    np.testing.assert_array_equal(out.argmax(-1), x.argmax(-1))
