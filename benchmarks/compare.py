"""Perf-trajectory report across the stacked PR benchmark artifacts.

Loads every ``BENCH_pr*.json`` in the repo root (the canonical
artifacts written by ``benchmarks/run.py`` — one per perf PR, plus
their ``.fast`` CI mirrors when present) and prints:

* a per-artifact summary: row count and the headline rows (anything
  whose derived payload carries a throughput/speedup/reduction figure),
* a trajectory table of those headline metrics in PR order, so "what
  did each perf PR actually buy" is one ``make bench-report`` away
  instead of a JSON spelunking session,
* ``BENCH_trajectory.json`` — the same trajectory as machine-readable
  records ({artifact, row, metric, value} per line of the table), so CI
  and downstream tooling consume the cross-PR history without scraping
  the printed table.

Missing artifacts are skipped with a note (a fresh clone before ``make
bench`` has none) — but an artifact that EXISTS and fails to parse is a
hard error (exit 1): a truncated or hand-mangled BENCH_pr*.json
silently vanishing from the report is how perf regressions hide.
Unknown row shapes still fall back to raw display rather than crashing.
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional

ROOT = Path(__file__).resolve().parent.parent

# derived-payload keys worth surfacing in the trajectory (ordered by
# how often people ask for them)
HEADLINE_KEYS = (
    "tok_s", "speedup_vs_base", "speedup_vs_oracle", "speedup_vs_b1",
    "speedup", "reduction", "traffic_reduction", "tokens_per_pass",
    "accepted_frac", "peak_kv_blocks", "ratio", "flat_in_k",
    "tokens_identical", "scaling_1to4", "amortized_tok_s",
    "per_device_peak_blocks", "bound_ok", "scaling_vs_1dev",
    "overhead_pct", "drift_pct", "tokens_match",
)


def _pr_key(path: Path) -> tuple:
    """Sort BENCH_pr5.json before BENCH_pr10.json, .fast after full."""
    m = re.search(r"pr(\d+)", path.name)
    return (int(m.group(1)) if m else 0, ".fast" in path.name)


def parse_derived(derived: str) -> Dict[str, str]:
    """'a=1;b=2.0x;note' → {'a': '1', 'b': '2.0x'} (bare notes dropped)."""
    out: Dict[str, str] = {}
    for part in str(derived).split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


class ArtifactError(RuntimeError):
    """An existing BENCH_pr*.json failed to parse — fail loudly."""


def load_artifacts(root: Path = ROOT) -> "List[tuple]":
    arts = []
    for path in sorted(root.glob("BENCH_pr*.json"), key=_pr_key):
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise ArtifactError(f"{path.name}: {e}") from e
        rows = doc.get("rows")
        if not isinstance(rows, list):
            raise ArtifactError(f"{path.name}: no 'rows' list")
        arts.append((path.name, rows))
    return arts


def headline_rows(rows: List[dict]) -> List[dict]:
    picked = []
    for r in rows:
        kv = parse_derived(r.get("derived", ""))
        if any(k in kv for k in HEADLINE_KEYS):
            picked.append(r)
    return picked


def trajectory_records(arts) -> List[dict]:
    """{artifact, row, metric, value} per headline metric, PR order."""
    recs = []
    for name, rows in arts:
        for r in headline_rows(rows):
            kv = parse_derived(r.get("derived", ""))
            for k in HEADLINE_KEYS:
                if k in kv:
                    recs.append({"artifact": name, "row": r["name"],
                                 "metric": k, "value": kv[k]})
    return recs


def trajectory_table(arts) -> List[str]:
    """One line per headline metric: artifact, row, metric, value."""
    lines = [f"{'artifact':<22} {'row':<38} {'metric':<18} value",
             "-" * 90]
    for rec in trajectory_records(arts):
        lines.append(f"{rec['artifact']:<22} {rec['row']:<38} "
                     f"{rec['metric']:<18} {rec['value']}")
    return lines


def main(argv: Optional[List[str]] = None) -> int:
    root = Path(argv[0]) if argv else ROOT
    try:
        arts = load_artifacts(root)
    except ArtifactError as e:
        print(f"error: unparsable benchmark artifact — {e}",
              file=sys.stderr)
        return 1
    if not arts:
        print(f"# no BENCH_pr*.json under {root} — run `make bench` first")
        return 0
    for name, rows in arts:
        picks = headline_rows(rows)
        print(f"\n== {name}: {len(rows)} rows, "
              f"{len(picks)} headline ==")
        for r in picks:
            print(f"  {r['name']},{r['us_per_call']},{r['derived']}")
    print("\n== perf trajectory ==")
    for line in trajectory_table(arts):
        print(line)
    out = root / "BENCH_trajectory.json"
    out.write_text(json.dumps(
        {"records": trajectory_records(arts)}, indent=2) + "\n")
    print(f"\n# wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
