"""Benchmark smoke gate: run the benchmark rows and exit nonzero if any
row raises — so the perf harness stays green in tier-1 workflows
(`make bench`, and the fast subset via tests/test_bench_smoke.py).

Usage: PYTHONPATH=src python benchmarks/smoke.py [--fast]
  --fast  only the acceptance-gated row groups: the PR 3 fused-vs-unfused
          rows + dispatch-count metric, the PR 5 paged-vs-dense serving
          rows (BENCH_pr5.fast.json), the PR 6 chunked-prefill
          kernelization rows (BENCH_pr6.fast.json), the PR 7
          speculative-decoding rows (BENCH_pr7.fast.json), the PR 8
          multi-device sharded-serving rows (BENCH_pr8.fast.json — the
          8-device arms run in a subprocess, see bench_shard), the
          PR 9 structured-sparsity rows (BENCH_pr9.fast.json), and the
          PR 10 serving-telemetry rows (BENCH_pr10.fast.json)
"""
from __future__ import annotations

import os
import sys

import run  # benchmarks/run.py (same directory when run as a script)


def main(argv) -> int:
    fast = "--fast" in argv
    benches = [run.bench_fused, run.bench_decode_dispatch,
               run.bench_paged, run.bench_prefill, run.bench_spec,
               run.bench_shard, run.bench_sparse, run.bench_obs] if fast \
        else run.ALL_BENCHES
    # fast mode must not clobber the full-row artifact (unless the
    # caller redirected the output explicitly)
    target = run.BENCH_JSON
    if fast and "REPRO_BENCH_JSON" not in os.environ:
        target = target.with_name("BENCH_pr3.fast.json")
    failures = run.run_benches(benches, keep_going=True)
    run.write_json(target)
    if failures:
        print(f"# FAILED rows in: {', '.join(failures)}", file=sys.stderr)
        return 1
    print(f"# {len(run._ROWS)} rows ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
