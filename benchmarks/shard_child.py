"""Multi-device benchmark child (PR 8): runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a subprocess
(the parent benchmark process owns a single default device) and prints
ONE JSON object to stdout for ``run.bench_shard`` to turn into rows.

Three arms (DESIGN.md §13):

* ``scaling`` — FIXED per-device block budget, growing mesh: data ∈
  {1, 4} at slots 16. More devices → data× aggregate KV capacity → more
  concurrently admitted decode slots → a larger weight-stream
  amortization denominator. The headline metric is the MODELED
  ``amortized_tokens_per_s`` (host CPU "devices" share the same cores,
  so wall-clock under-reports the win; it is included as indicative).
* ``bound`` — IDENTICAL pool/workload across device counts: peak block
  occupancy is mesh-invariant (block ids are global), so per-device
  peak = peak/data exactly — the acceptance bound
  per_device ≤ single_device/data + 1 by construction.
* ``disagg`` — prefill pool (data=2) handing finished prompts to a
  decode pool (data=4); asserts token identity against the unified
  single-device run and reports the handoff traffic.
"""
import dataclasses
import json
import sys
import time

import numpy as np


def main() -> None:
    import jax
    assert len(jax.devices()) >= 8, jax.devices()

    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.paged import DisaggScheduler, Scheduler

    # num_kv_heads must divide the data axis (4): 4 kv heads, f32 smoke
    import jax.numpy as jnp
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_len, bs, new, slots = 256, 16, 48, 16
    lens = [12, 24, 16, 28, 20, 12, 16, 24, 12, 20, 28, 16, 24, 12, 20, 16]
    reqs = [rng.integers(1, cfg.vocab_size, size=n).tolist() for n in lens]
    toks = len(reqs) * new

    def run_arm(sch):
        def once():
            for i, p in enumerate(reqs):
                sch.submit(Request(rid=i, prompt=p, max_new=new))
            return sch.run()
        out = once()                      # warm: compile
        sch.reset_stats()
        t0 = time.perf_counter()
        out2 = once()
        dt = time.perf_counter() - t0
        assert out2 == out
        return dt, out

    out = {}

    # ---- scaling: fixed per-device budget, growing mesh ---------------
    # 18 blocks/device keeps the 1-device arm on the steep side of the
    # amortization curve (~4 concurrent slots); 4 devices reach ~14
    per_dev_blocks = 18
    scaling = []
    ref = None
    for data in (1, 4):
        mesh = make_serving_mesh(data=data).mesh
        sch = Scheduler(cfg, params, slots=slots, max_len=max_len,
                        block_size=bs, chunk=16, prefix_cache=False,
                        num_blocks=data * per_dev_blocks, mesh=mesh)
        dt, done = run_arm(sch)
        if ref is None:
            ref = done
        else:
            assert done == ref, "scaling arm diverged"
        rep = sch.stream_amortization_report()
        scaling.append({
            "data": data,
            "num_blocks": data * per_dev_blocks,
            "wall_s": dt,
            "wall_tok_s": toks / dt,
            "mean_active": rep["mean_active"],
            "amortized_tokens_per_s": rep["amortized_tokens_per_s"],
            "peak_blocks": sch.pool.peak_in_use,
            "per_device_peak_blocks": sch.per_device_peak_blocks(),
            "data_shards": sch.data_shards(),
            "tokens_identical": done == ref,
        })
    out["scaling"] = scaling
    out["scaling_x"] = (scaling[1]["amortized_tokens_per_s"]
                        / scaling[0]["amortized_tokens_per_s"])

    # ---- bound: identical pool + workload, device count varies --------
    bound = []
    for data in (1, 4):
        mesh = make_serving_mesh(data=data).mesh
        sch = Scheduler(cfg, params, slots=slots, max_len=max_len,
                        block_size=bs, chunk=16, prefix_cache=False,
                        num_blocks=120, mesh=mesh)
        run_arm(sch)
        bound.append({"data": data, "peak_blocks": sch.pool.peak_in_use,
                      "per_device_peak_blocks":
                          sch.per_device_peak_blocks()})
    out["bound"] = bound
    out["bound_ok"] = (bound[1]["per_device_peak_blocks"]
                       <= bound[0]["peak_blocks"] / 4 + 1)

    # ---- disaggregated prefill/decode ---------------------------------
    base = Scheduler(cfg, params, slots=slots, max_len=max_len,
                     block_size=bs, chunk=16, prefix_cache=False)
    _, ref1 = run_arm(base)
    dm = make_serving_mesh(data=4, prefill_data=2)
    dis = DisaggScheduler(cfg, params, prefill_mesh=dm.prefill_mesh,
                          decode_mesh=dm.mesh, slots=slots,
                          max_len=max_len, block_size=bs, chunk=16)
    for i, p in enumerate(reqs):
        dis.submit(Request(rid=i, prompt=p, max_new=new))
    t0 = time.perf_counter()
    done = dis.run()
    dt = time.perf_counter() - t0
    rep = dis.report()
    out["disagg"] = {
        "wall_s": dt,
        "identical": done == ref1,
        **rep,
    }
    assert done == ref1, "disaggregated run diverged"

    print(json.dumps(out))


if __name__ == "__main__":
    sys.exit(main())
