"""Benchmark harness — one function per paper table/figure, plus kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  * table1_*  — Table I   dataflow access counts (llama2-7b GEMM set)
  * fig8a/b   — Fig 8     DRAM-access / CIM-update reductions
  * fig9a/b   — Fig 9     prefill / decode latency reductions
  * table2_*  — Table II  TOPS, TOPS/W, prefill ms, decode tok/s
  * kernel_*  — wall time of the jitted ops on CPU (indicative only; the
                graded perf story is the dry-run roofline analysis)

``us_per_call`` is the wall time of evaluating the row's underlying
function (analytic rows are effectively free); ``derived`` carries the
reproduced quantity and, where the paper publishes the same number, the
paper value for side-by-side comparison.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import Dataflow, TileConfig, access_counts
from repro.core.quant import QuantConfig, quantize_weight
from repro.kernels import ops, ref
from repro.sim import perf_model as pm

BENCH_JSON = Path(os.environ.get(
    "REPRO_BENCH_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr3.json"))
# PR 5 rows (paged-vs-dense serving) land in their own artifact so the
# paged acceptance numbers are greppable without the kernel rows
PR5_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR5_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr5.json"))
# PR 6 rows (chunked-prefill kernelization) likewise
PR6_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR6_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr6.json"))
# PR 7 rows (speculative / beam decoding on COW block tables) likewise
PR7_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR7_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr7.json"))
# PR 8 rows (multi-device sharded paged serving) likewise
PR8_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR8_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr8.json"))
# PR 9 rows (structured N:M weight sparsity, §14) likewise
PR9_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR9_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr9.json"))
# PR 10 rows (serving telemetry: overhead, export validity, drift) likewise
PR10_JSON = Path(os.environ.get(
    "REPRO_BENCH_PR10_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_pr10.json"))
_ROWS = []


def _timeit(fn, n=3):
    out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6, out


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": str(derived)})


def bench_table1() -> None:
    """Table I: element access counts for each dataflow (representative
    4096x4096 GEMM, M=1024 tokens, 128/256/256 tiles)."""
    tc = TileConfig(M=1024, N=4096, K=4096, m=128, n=256, k=256)
    for df in Dataflow:
        us, c = _timeit(lambda df=df: access_counts(df, tc))
        _row(f"table1_{df.value}", us,
             f"in={c['input']};w={c['weight']};out={c['output']};"
             f"upd={c['cim_update']}")


def bench_fig8() -> None:
    us, r = _timeit(pm.fig8a_dram_reduction)
    _row("fig8a_dram_reduction", us,
         f"repro={r['reduction']:.3f};paper={r['paper']}")
    us, r = _timeit(pm.fig8b_update_reduction)
    _row("fig8b_update_reduction", us,
         f"repro={r['reduction']:.3f};paper={r['paper']}")


def bench_fig9() -> None:
    us, r = _timeit(pm.fig9a_prefill_reduction)
    _row("fig9a_prefill_reduction", us,
         f"repro={r['reduction']:.4f};paper={r['paper']};"
         f"per_token_ms={r['per_token_ms']:.2f};paper_ms=4.2")
    us, r = _timeit(pm.fig9b_decode_reductions)
    _row("fig9b_rcw_reduction", us,
         f"repro={r['rcw_reduction']:.4f};paper={r['paper_rcw']}")
    _row("fig9b_fusion_reduction", 0.0,
         f"repro={r['fusion_reduction']:.4f};paper={r['paper_fusion']}")
    _row("fig9b_total_reduction", 0.0,
         f"repro={r['total_reduction']:.4f};paper={r['paper_total']}")
    _row("fig9b_decode_tokens_per_s", 0.0,
         f"repro={r['tokens_per_s']:.2f};paper={r['paper_tokens_per_s']}")


def bench_table2() -> None:
    us, t = _timeit(pm.table2_summary)
    _row("table2_throughput_tops", us,
         f"repro={t['throughput_tops']:.2f};paper={t['paper_tops']}")
    _row("table2_energy_eff", 0.0,
         f"repro={t['energy_eff_tops_per_w']};paper={t['paper_tops_per_w']}")
    _row("table2_prefill_ms", 0.0,
         f"repro={t['prefill_per_token_ms']:.2f};paper=4.2")
    _row("table2_decode_tok_s", 0.0,
         f"repro={t['decode_tokens_per_s']:.2f};paper=26.87")
    _row("table2_energy_per_token_mj", 0.0,
         f"repro={t['energy_per_token_mj']:.2f}")


def bench_kernels() -> None:
    """Jitted op wall-times on CPU (ref lowering path, as the dry-run
    lowers it off-TPU)."""
    rng = np.random.default_rng(0)
    M, N, K = 256, 1024, 1024
    w = jnp.asarray(rng.standard_normal((N, K)).astype(np.float32))
    qw = quantize_weight(w, QuantConfig("w4a8", 128))
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))

    f = jax.jit(lambda x: ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4))
    us, _ = _timeit(lambda: f(x), n=10)
    flops = 2 * M * N * K
    _row("kernel_w4a8_matmul_1024", us, f"gflops={flops/us/1e3:.1f}")

    xs = jnp.asarray(rng.standard_normal((64, 2048)).astype(np.float32))
    g = jax.jit(lambda x: ref.group_softmax_ref(x, 64))
    us, _ = _timeit(lambda: g(xs), n=10)
    _row("kernel_group_softmax_64x2048", us,
         f"gelem_s={64*2048/us/1e3:.2f}")

    gamma = jnp.ones(2048)
    h = jax.jit(lambda x: ref.group_rmsnorm_ref(x, gamma, 128))
    us, _ = _timeit(lambda: h(xs), n=10)
    _row("kernel_group_rmsnorm_64x2048", us,
         f"gelem_s={64*2048/us/1e3:.2f}")

    q = jnp.asarray(rng.standard_normal((1, 8, 256, 64)).astype(np.float32))
    kv = jnp.asarray(rng.standard_normal((1, 2, 256, 64)).astype(np.float32))
    a = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us, _ = _timeit(lambda: a(q, kv, kv), n=10)
    _row("kernel_attention_gqa_256", us, "oracle_path")


def bench_fused() -> None:
    """PR 3 rows: the fused-epilogue chain vs its unfused composition.

    Wall times compare ONE jitted dispatch of the whole chain against the
    per-op jit dispatch sequence the unfused path issues (CPU ref
    lowering; indicative — the graded claim is the dispatch-count drop).
    """
    rng = np.random.default_rng(0)
    M, N, F = 8, 1024, 2048
    wg = quantize_weight(jnp.asarray(
        rng.standard_normal((N, F)).astype(np.float32)), QuantConfig("w4a8", 128))
    wi = quantize_weight(jnp.asarray(
        rng.standard_normal((N, F)).astype(np.float32)), QuantConfig("w4a8", 128))
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    gamma = jnp.ones(N)

    # unfused: norm, gate GEMM, up GEMM, silu, multiply — 5 dispatches
    norm_f = jax.jit(lambda x: ref.group_rmsnorm_ref(x, gamma, 128))
    mm_g = jax.jit(lambda h: ref.ws_ocs_matmul_ref(h, wg.data, wg.scale, bits=4))
    mm_i = jax.jit(lambda h: ref.ws_ocs_matmul_ref(h, wi.data, wi.scale, bits=4))
    silu = jax.jit(jax.nn.silu)
    mul = jax.jit(jnp.multiply)

    def unfused():
        h = norm_f(x)
        return mul(silu(mm_g(h)), mm_i(h))

    us_u, want = _timeit(unfused, n=10)
    _row("kernel_unfused_norm_glu_1024x2048", us_u, "dispatches=5")

    fused = jax.jit(lambda x: ref.fused_matmul_ref(
        x, wg.data, wg.scale, bits=4, gamma=gamma, norm_group=128,
        act="silu", w2_data=wi.data, w2_scale=wi.scale))
    us_f, got = _timeit(lambda: fused(x), n=10)
    err = float(jnp.abs(got - want).max())
    _row("kernel_fused_norm_glu_1024x2048", us_f,
         f"dispatches=1;speedup={us_u / max(us_f, 1e-9):.2f}x;maxerr={err:.1e}")

    # attention decode: QK^T → group-softmax → PV vs one fused call
    B, H, Hkv, S, D = 4, 8, 2, 512, 64
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    lens = jnp.full((B,), S, jnp.int32)

    qk = jax.jit(lambda q, k: jnp.einsum(
        "bhgd,bshd->bhgs", q.reshape(B, Hkv, H // Hkv, D), k) * D ** -0.5)
    sm = jax.jit(lambda s: ref.group_softmax_ref(s, 64))
    pv = jax.jit(lambda p, v: jnp.einsum("bhgs,bshd->bhgd", p, v))

    def unfused_attn():
        return pv(sm(qk(q, k)), v).reshape(B, H, D)

    us_u, want = _timeit(unfused_attn, n=10)
    _row("kernel_unfused_attn_decode_512", us_u, "dispatches=3")

    fused_attn = jax.jit(lambda q, k, v: ref.attention_decode_ref(
        q, k, v, lens, group_size=64, use_lut=True))
    us_f, got = _timeit(lambda: fused_attn(q, k, v), n=10)
    err = float(jnp.abs(got - want).max())
    _row("kernel_fused_attn_decode_512", us_f,
         f"dispatches=1;speedup={us_u / max(us_f, 1e-9):.2f}x;maxerr={err:.1e}")


def bench_decode_dispatch() -> None:
    """The §7 acceptance metric: jaxpr equation count (and pallas_call
    kernel launches) of one decode step through serve/engine.py, fused
    vs unfused, on the w4a8-quantized smoke model."""
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.engine import Engine, quantize_params

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", use_lut_softmax=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg)

    ops.force_pallas(True)     # count the kernel path, not the CPU oracle
    try:
        counts = {}
        for fused in (False, True):
            eng = Engine(cfg.replace(fuse_epilogue=fused), qp, max_len=64)
            t0 = time.perf_counter()
            total = eng.decode_eqn_count()   # first call pays the trace
            us = (time.perf_counter() - t0) * 1e6
            kernels = eng.decode_eqn_count(primitive="pallas_call")
            tag = "fused" if fused else "unfused"
            counts[tag] = {"eqns": total, "pallas_calls": kernels}
            _row(f"decode_dispatch_{tag}", us,
                 f"jaxpr_eqns={total};pallas_calls={kernels}")
    finally:
        ops.force_pallas(None)
    red = 1 - counts["fused"]["eqns"] / counts["unfused"]["eqns"]
    _row("decode_dispatch_reduction", 0.0,
         f"eqn_reduction={red:.3f};paper_fusion_latency_reduction=0.6917")


def bench_paged() -> None:
    """PR 5 rows: dense ContinuousBatcher vs paged Scheduler on a skewed
    workload (mixed 8–56-token prompts behind a shared 16-token system
    prefix) at slots ∈ {4, 16} — wall tokens/sec (incl. compile; CPU ref
    lowering, indicative) and the peak KV blocks the paged pool actually
    referenced vs the slots×max_len dense allocation."""
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.batching import ContinuousBatcher, Request
    from repro.serve.paged import Scheduler

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=256)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_len, bs, new = 128, 16, 6
    sysp = rng.integers(1, cfg.vocab_size, size=16).tolist()
    lens = [8, 40, 16, 56, 24, 8, 32, 48, 8, 16, 40, 24]
    reqs = [sysp + rng.integers(1, cfg.vocab_size, size=n).tolist()
            for n in lens]

    for slots in (4, 16):
        def run_dense():
            cb = ContinuousBatcher(cfg, params, slots=slots,
                                   max_len=max_len)
            for i, p in enumerate(reqs):
                cb.submit(Request(rid=i, prompt=p, max_new=new))
            return cb.run()

        def run_paged():
            sch = Scheduler(cfg, params, slots=slots, max_len=max_len,
                            block_size=bs, chunk=16)
            for i, p in enumerate(reqs):
                sch.submit(Request(rid=i, prompt=p, max_new=new))
            return sch.run(), sch

        t0 = time.perf_counter()
        run_dense()
        t_dense = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, sch = run_paged()
        t_paged = time.perf_counter() - t0
        toks = len(reqs) * new
        dense_blocks = slots * (max_len // bs)
        amort = sch.stream_amortization_report()
        _row(f"paged_dense_tok_s_slots{slots}", t_dense * 1e6,
             f"tok_s={toks / t_dense:.1f};kv_blocks={dense_blocks}")
        _row(f"paged_paged_tok_s_slots{slots}", t_paged * 1e6,
             f"tok_s={toks / t_paged:.1f};"
             f"peak_kv_blocks={sch.pool.peak_in_use};"
             f"dense_equiv_blocks={dense_blocks};"
             f"kv_bytes_peak={sch.kv_bytes_peak()};"
             f"kv_bytes_dense={sch.kv_bytes_dense_equiv()}")
        _row(f"paged_stream_amortization_slots{slots}", 0.0,
             f"mean_active={amort['mean_active']:.2f};"
             f"speedup_vs_b1={amort['speedup_vs_b1']:.2f}x")


def _env_arm(env):
    """Context manager pinning the chunk-prefill dispatch switches."""
    import contextlib

    @contextlib.contextmanager
    def cm():
        keys = ("REPRO_CHUNK_ORACLE", "REPRO_OPT_PAGEDFLASH")
        old = {k: os.environ.pop(k, None) for k in keys}
        os.environ.update(env)
        try:
            yield
        finally:
            for k in keys:
                os.environ.pop(k, None)
            os.environ.update({k: v for k, v in old.items() if v is not None})
    return cm()


def bench_prefill() -> None:
    """PR 6 rows (BENCH_pr6.json): chunked-prefill attention kernelized.

    Three tiers of evidence (DESIGN.md §11):

    * ``prefill_attn_*`` — op wall-time of one tick's chunk attention at
      ``slots`` concurrent requests (B = slots): the PR 5 dense-oracle
      (gather the pool dense, materialize (C, max_len) scores) vs the
      offset-causal *flash* composition (still gathers dense, but
      online-softmax over the written prefix only) vs *paged-flash*
      (``ops.paged_flash_prefill``: block-table fetch, no dense copy —
      the off-TPU O(written-prefix) scan lowering stands in for the
      Pallas kernel this container cannot lower).
    * ``prefill_sched_*`` — end-to-end Scheduler wall-clock on a pure
      chunked-prefill workload (max_new=1: the first token comes from
      the chunk logits, so no decode ticks), oracle arm
      (REPRO_CHUNK_ORACLE=1) vs flash arm (REPRO_OPT_PAGEDFLASH=1), with
      a right-sized pool (~2×nbmax blocks — the paged setting; a
      dense-equivalent pool just measures pool-copy traffic). Greedy
      outputs are asserted identical across arms.
    * ``prefill_dispatch_*`` — ``Engine.prefill_eqn_count`` jaxpr
      accounting of one chunk step, kernel path vs oracle: on the
      kernel path attention + every layer matmul is Pallas-resident
      (dense dot_generals == 1, the LM head) and the oracle's two
      densify gathers per pool vanish — the "no dense KV on
      prefix-cache hit" invariant, counted.
    """
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.engine import Engine, quantize_params
    from repro.serve.paged import Scheduler

    rng = np.random.default_rng(0)

    # ---- op-level: one tick of chunk attention at B = slots ----------
    Hkv, D, H, BS, C, NBMAX = 2, 32, 2, 16, 16, 256
    NB = 2 * NBMAX + 2
    kp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((NB, BS, Hkv, D)).astype(np.float32))

    def flash_dense(q, kp, vp, bt, st):
        # offset-causal flash over the *densified* prefix: pay the PR 5
        # gather, then view the dense copy as a per-request pool (identity
        # table) and run the online-softmax scan — isolates densify cost
        # (flash vs paged-flash) from materialized-score cost (oracle
        # vs flash)
        kg = ref.gather_paged_kv_ref(kp, bt)
        vg = ref.gather_paged_kv_ref(vp, bt)
        B, nbmax = bt.shape
        ident = (jnp.arange(B, dtype=jnp.int32)[:, None] * nbmax
                 + jnp.arange(nbmax, dtype=jnp.int32)[None, :])
        return ref.paged_flash_prefill_scan_ref(
            q, kg.reshape(B * nbmax, BS, Hkv, D),
            vg.reshape(B * nbmax, BS, Hkv, D), ident, st)

    for slots in (4, 16):
        q = jnp.asarray(
            rng.standard_normal((slots, H, C, D)).astype(np.float32))
        nb_used = 10                          # ~144-token written prefix
        bt = np.zeros((slots, NBMAX), np.int32)
        for b in range(slots):
            bt[b, :nb_used] = 1 + ((b * nb_used + np.arange(nb_used))
                                   % (NB - 1))
        bt = jnp.asarray(bt)
        st = jnp.full((slots,), (nb_used - 1) * BS, jnp.int32)

        arms = [
            ("oracle", jax.jit(lambda q, kp, vp, bt, st:
                               ref.paged_flash_prefill_ref(q, kp, vp, bt, st))),
            ("flash", jax.jit(flash_dense)),
            ("pagedflash", jax.jit(lambda q, kp, vp, bt, st:
                                   ref.paged_flash_prefill_scan_ref(
                                       q, kp, vp, bt, st))),
        ]
        us0, want = _timeit(lambda: arms[0][1](q, kp, vp, bt, st), n=10)
        _row(f"prefill_attn_oracle_slots{slots}", us0,
             f"dense_len={NBMAX * BS};written={nb_used * BS}")
        for name, fn in arms[1:]:
            us, got = _timeit(lambda fn=fn: fn(q, kp, vp, bt, st), n=10)
            err = float(jnp.abs(got - want).max())
            _row(f"prefill_attn_{name}_slots{slots}", us,
                 f"speedup_vs_oracle={us0 / max(us, 1e-9):.2f}x;"
                 f"maxerr={err:.1e}")

    # ---- scheduler end-to-end: pure chunked-prefill workload ---------
    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=256)
    params = api.init(jax.random.PRNGKey(0), cfg)
    max_len, bs = 4096, 16
    sysp = rng.integers(1, cfg.vocab_size, size=32).tolist()
    lens = [48, 96, 64, 112, 80, 48, 64, 96, 48, 80, 112, 64]
    reqs = [sysp + rng.integers(1, cfg.vocab_size, size=n).tolist()
            for n in lens]
    ptoks = sum(len(p) for p in reqs)

    def run_arm(env, slots):
        with _env_arm(env):
            sch = Scheduler(cfg, params, slots=slots, max_len=max_len,
                            block_size=bs, chunk=16,
                            num_blocks=2 * (max_len // bs) + 4,
                            prefix_cache=False)

            def once():
                for i, p in enumerate(reqs):
                    sch.submit(Request(rid=i, prompt=p, max_new=1))
                return sch.run()

            done = once()                       # warm the jitted chunk step
            sch.reset_stats()                   # report the timed run only
            t0 = time.perf_counter()
            once()
            return time.perf_counter() - t0, done, sch

    for slots in (4, 16):
        t_o, done_o, _ = run_arm({"REPRO_CHUNK_ORACLE": "1"}, slots)
        t_f, done_f, sch = run_arm({"REPRO_OPT_PAGEDFLASH": "1"}, slots)
        assert done_o == done_f, "arm outputs diverged"
        amort = sch.stream_amortization_report()
        _row(f"prefill_sched_oracle_slots{slots}", t_o * 1e6,
             f"prefill_tok_s={ptoks / t_o:.1f}")
        _row(f"prefill_sched_flash_slots{slots}", t_f * 1e6,
             f"prefill_tok_s={ptoks / t_f:.1f};"
             f"speedup_vs_oracle={t_o / t_f:.2f}x;tokens_identical=True;"
             f"mean_prefill_launches={amort['mean_prefill_launches']:.2f}")

    # ---- dispatch accounting: kernel vs oracle chunk-step jaxpr ------
    dcfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", use_lut_softmax=True)
    qp = quantize_params(api.init(jax.random.PRNGKey(0), dcfg), dcfg)
    ops.force_pallas(True)     # count the kernel path, not the CPU oracle
    try:
        counts = {}
        for tag, env in (("kernel", {}),
                         ("oracle", {"REPRO_CHUNK_ORACLE": "1"})):
            with _env_arm(env):
                eng = Engine(dcfg, qp, max_len=64)
                t0 = time.perf_counter()
                total = eng.prefill_eqn_count(chunk=16)
                us = (time.perf_counter() - t0) * 1e6
                counts[tag] = {
                    "eqns": total,
                    "pallas": eng.prefill_eqn_count(
                        chunk=16, primitive="pallas_call"),
                    "dot": eng.prefill_eqn_count(
                        chunk=16, primitive="dot_general"),
                    "gather": eng.prefill_eqn_count(
                        chunk=16, primitive="gather"),
                }
                c = counts[tag]
                _row(f"prefill_dispatch_{tag}", us,
                     f"jaxpr_eqns={c['eqns']};pallas_calls={c['pallas']};"
                     f"dot_general={c['dot']};gather={c['gather']}")
    finally:
        ops.force_pallas(None)
    _row("prefill_dispatch_densify_evidence", 0.0,
         f"kernel_dot_general={counts['kernel']['dot']} (the LM head);"
         f"oracle_extra_dot_general="
         f"{counts['oracle']['dot'] - counts['kernel']['dot']};"
         f"oracle_extra_gather="
         f"{counts['oracle']['gather'] - counts['kernel']['gather']}")

    # ---- analytic kernel-residency row -------------------------------
    us, r = _timeit(pm.chunk_prefill_residency_report)
    _row("prefill_residency_model", us,
         f"dense_oracle_ms={r['dense_oracle_ms']:.2f};"
         f"kernel_resident_ms={r['kernel_resident_ms']:.2f};"
         f"traffic_reduction={r['traffic_reduction']:.3f}")


def bench_spec() -> None:
    """PR 7 rows (BENCH_pr7.json): speculative decoding on copy-on-write
    block tables — tokens per weight-stream pass.

    ``spec_sched_*`` is the headline sweep: the paged Scheduler on a
    decode-heavy workload at slots ∈ {4, 16}, non-speculative baseline
    (the PR 6 configuration) vs k=4 oracle-draft speculation across
    acceptance rates. The oracle draft is free by construction, so the
    sweep isolates the verify-path economics: one k+1-wide
    ``api.verify_step`` dispatch replaces up to k+1 one-token decode
    dispatches, with every arm asserted token-identical to the baseline.
    ``spec_verify_dispatch`` shows the count that makes this work — the
    verify pass's jaxpr is flat in k. ``spec_model_*`` rows are the
    analytic counterpart (``pm.speculative_decode_latency``): on the
    modeled chip the stream term is already divided by the active slots,
    so speculation's win comes from amortizing it over accepted tokens
    and the sweep locates the acceptance crossover where the (k+1)×
    MAC/NL inflation eats the saving. ``spec_beam_*`` rows measure the
    other COW consumer: n-best forking's peak KV blocks vs n independent
    streams."""
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.engine import Engine
    from repro.serve.paged import Scheduler
    from repro.serve.spec_decode import OracleDraft, SpecConfig

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=256)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_len, bs, k, new = 256, 16, 4, 48
    lens = [12, 24, 16, 28, 20, 12, 16, 24, 12, 20, 28, 16, 24, 12, 20, 16]
    reqs = [rng.integers(1, cfg.vocab_size, size=n).tolist() for n in lens]

    def run_arm(slots, spec):
        """One scheduler per arm: warm run compiles the jitted steps
        (each Scheduler owns fresh jit closures), timed run re-submits
        the same workload against the warm jits — the measurement is
        steady-state serving, not tracing."""
        sch = Scheduler(cfg, params, slots=slots, max_len=max_len,
                        block_size=bs, chunk=16, prefix_cache=False,
                        spec=spec)

        def once():
            for i, p in enumerate(reqs):
                sch.submit(Request(rid=i, prompt=p, max_new=new))
            return sch.run()

        once()
        sch.reset_stats()   # warm-run counters would skew the arm's
        t0 = time.perf_counter()               # acceptance/peak stats
        done = once()
        return time.perf_counter() - t0, done, sch

    toks = len(reqs) * new
    for slots in (4, 16):
        t_base, base, _ = run_arm(slots, None)
        _row(f"spec_sched_base_slots{slots}", t_base * 1e6,
             f"tok_s={toks / t_base:.1f};k=0")
        refseqs = {(i, 0): reqs[i] + base[i] for i in range(len(reqs))}
        for rate in (0.3, 0.5, 0.7, 0.9, 1.0):
            spec = SpecConfig(draft=OracleDraft(
                refseqs, accept_rate=rate, vocab_size=cfg.vocab_size), k=k)
            t, done, sch = run_arm(slots, spec)
            assert done == base, "speculative arm diverged from baseline"
            rep = sch.spec_report()
            # dialed = the per-position draft-match probability α;
            # accepted/drafted runs lower because every pass re-drafts
            # the positions behind its first mismatch
            _row(f"spec_sched_a{int(rate * 100):03d}_slots{slots}",
                 t * 1e6,
                 f"tok_s={toks / t:.1f};k={k};dialed={rate:.2f};"
                 f"speedup_vs_base={t_base / t:.2f}x;"
                 f"accepted_frac={rep['accept_rate']:.2f};"
                 f"tokens_per_pass={rep['tokens_per_pass']:.2f};"
                 f"tokens_identical=True")

    # ---- beam forking: peak KV blocks, n forks vs n streams ----------
    # prompt-heavy regime: the prompt is stored once across forks, each
    # fork privatizes only its COW'd tail + generated blocks. The
    # prompt length is deliberately NOT block-aligned so the shared
    # partial tail block forces a copy-on-write per fork (cow_copies>0).
    nb, beam_new = 4, 16
    prompt = rng.integers(1, cfg.vocab_size, size=90).tolist()
    sch1 = Scheduler(cfg, params, slots=1, max_len=max_len, block_size=bs,
                     chunk=16, prefix_cache=False)
    sch1.submit(Request(rid=0, prompt=prompt, max_new=beam_new))
    sch1.run()
    schn = Scheduler(cfg, params, slots=nb, max_len=max_len, block_size=bs,
                     chunk=16, prefix_cache=False)
    schn.submit(Request(rid=0, prompt=prompt, max_new=beam_new, n_best=nb))
    schn.run()
    _row("spec_beam_fork_blocks", 0.0,
         f"n_best={nb};peak_blocks={schn.pool.peak_in_use};"
         f"single_stream_blocks={sch1.pool.peak_in_use};"
         f"ratio={schn.pool.peak_in_use / sch1.pool.peak_in_use:.2f};"
         f"cow_copies={schn.pool.cow_copies}")

    # ---- dispatch accounting: verify jaxpr flat in k -----------------
    eng = Engine(cfg, params, max_len=64)
    t0 = time.perf_counter()
    counts = {kk: eng.verify_eqn_count(batch=4, k=kk) for kk in (1, 4, 7)}
    us = (time.perf_counter() - t0) * 1e6
    _row("spec_verify_dispatch", us,
         f"eqns_k1={counts[1]};eqns_k4={counts[4]};eqns_k7={counts[7]};"
         f"flat_in_k={counts[1] == counts[4] == counts[7]}")

    # ---- analytic speculation-adjusted decode latency ----------------
    for slots in (4, 16):
        base_us = pm.amortized_decode_latency(slots) * 1e6
        sweep = ";".join(
            f"a{int(r * 100):03d}="
            f"{base_us / (pm.speculative_decode_latency(slots, k, r) * 1e6):.2f}x"
            for r in (0.3, 0.5, 0.7, 0.9, 1.0))
        _row(f"spec_model_speedup_slots{slots}", 0.0,
             f"k={k};amortized_us={base_us:.1f};{sweep}")


def bench_shard() -> None:
    """PR 8 rows (BENCH_pr8.json): the paged serving engine sharded
    across a host mesh (DESIGN.md §13).

    The multi-device arms run in ONE subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (this process
    owns a single default device; see benchmarks/shard_child.py for the
    arm definitions). Host CPU "devices" share the machine's cores, so
    per-arm wall-clock is indicative only — the headline metrics are the
    MODELED amortized decode throughput (more aggregate KV capacity →
    more concurrently admitted slots → a larger weight-stream
    amortization denominator) and the per-device peak-KV bound, both of
    which are device-count facts, not timing facts. Token identity of
    every arm against the single-device engine is asserted in the child
    and re-asserted (sweep form) in tests/test_multidevice.py.

    ``shard_model_*`` rows are the analytic counterparts:
    ``pm.sharded_kv_scaleout_report`` (fixed per-device block budget,
    growing mesh) and ``pm.disaggregated_serving_report`` (prefill pool
    overlapping the decode pool, KV handoff over the interconnect)."""
    import subprocess
    import sys

    child = Path(__file__).resolve().parent / "shard_child.py"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, str(child)], env=env,
                          capture_output=True, text=True, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-4000:]
    r = json.loads(proc.stdout.splitlines()[-1])

    for arm in r["scaling"]:
        _row(f"shard_sched_scaleout_data{arm['data']}", arm["wall_s"] * 1e6,
             f"amortized_tok_s={arm['amortized_tokens_per_s']:.1f};"
             f"mean_active={arm['mean_active']:.2f};"
             f"num_blocks={arm['num_blocks']};"
             f"peak_blocks={arm['peak_blocks']};"
             f"per_device_peak_blocks={arm['per_device_peak_blocks']:.2f};"
             f"data_shards={arm['data_shards']};"
             f"wall_tok_s={arm['wall_tok_s']:.1f};"
             f"tokens_identical={arm['tokens_identical']}")
    _row("shard_sched_scaleout_headline", us,
         f"scaling_1to4={r['scaling_x']:.2f}x;target=1.5x;"
         f"met={r['scaling_x'] >= 1.5}")

    b1, b4 = r["bound"]
    _row("shard_kv_per_device_bound", 0.0,
         f"peak_blocks_1dev={b1['peak_blocks']};"
         f"peak_blocks_4dev={b4['peak_blocks']};"
         f"per_device_peak_4dev={b4['per_device_peak_blocks']:.2f};"
         f"bound={b1['peak_blocks'] / 4 + 1:.2f};"
         f"bound_ok={r['bound_ok']}")

    d = r["disagg"]
    _row("shard_disagg_prefill_decode", d["wall_s"] * 1e6,
         f"handoffs={d['handoffs']};handoff_bytes={d['handoff_bytes']};"
         f"prefill_peak_blocks={d['prefill_peak_blocks']};"
         f"decode_peak_blocks={d['decode_peak_blocks']};"
         f"tokens_identical={d['identical']}")

    # ---- analytic counterparts on the modeled RCW-CIM chip -----------
    for data in (1, 2, 4, 8):
        m = pm.sharded_kv_scaleout_report(data, per_device_blocks=64)
        _row(f"shard_model_scaleout_data{data}", 0.0,
             f"concurrent_slots={m['concurrent_slots']};"
             f"tokens_per_s={m['tokens_per_s']:.0f};"
             f"scaling_vs_1dev={m['scaling_vs_1dev']:.2f}x")
    dm = pm.disaggregated_serving_report()
    _row("shard_model_disagg", 0.0,
         f"unified_s={dm['unified_s']:.2f};disagg_s={dm['disagg_s']:.2f};"
         f"speedup={dm['speedup']:.2f}x;"
         f"handoff_s={dm['handoff_s']:.3f};"
         f"handoff_MB_per_req={dm['handoff_bytes_per_req'] / 1e6:.0f}")


def bench_sparse() -> None:
    """PR 9 rows (BENCH_pr9.json): structured N:M weight sparsity
    through the WS-OCS kernel family (DESIGN.md §14).

    * ``sparse_matmul_speedup`` — op-level wall time of the jitted
      row-skip lowering (gather kept activation columns, contract only
      the Nc kept rows) vs the jitted dense-masked baseline GEMM at the
      same logical shape. This is the genuinely-less-work arm: 2:4 halves
      the contraction, target ≥1.5×.
    * ``sparse_panel_bytes`` — compressed weight-panel DMA bytes per
      K-tile vs dense for the bitmask ('col') format the sparse RCW
      kernel double-buffers (w4 2:4 = 3 bits/elem → 25 % fewer bytes).
    * ``sparse_bitexact_int`` — the interpret-mode sparse fused kernel in
      int-accumulation mode vs the jitted dense-mask int reference, bit
      compared (the §14 serving-equivalence contract).
    * ``sparse_sched_*`` — a 2:4-sparse checkpoint vs its dense-masked
      equivalent through the paged Scheduler: token identity + wall
      tokens/sec (CPU ref lowering, indicative).
    * ``sparse_model_*`` — analytic RCW-CIM rows from
      ``pm.sparsity_report``: weight/DRAM/update reductions and the
      sparsity-gated decode/prefill speedups next to Fig-8/Fig-9."""
    from repro.core.quant import SparsityConfig, nm_prune_mask, sparsify_weight
    from repro.kernels import sparse_matmul as sm

    # ---- op-level: row-skip vs dense-masked GEMM ---------------------
    M, N, K = 128, 2048, 2048
    sp = SparsityConfig(2, 4, "row")
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((N, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    qc = QuantConfig("w4a8", 128)
    sw = sparsify_weight(w, qc, sp)
    wd = w * nm_prune_mask(w, sp).astype(w.dtype)
    qw = quantize_weight(wd, qc)

    dense_fn = jax.jit(
        lambda a, d, s: ref.ws_ocs_matmul_ref(a, d, s, bits=4))
    skip_fn = jax.jit(
        lambda a, d, s, i: ref.sparse_skip_matmul_ref(a, d, s, i,
                                                      n=2, m=4, bits=4))
    us_d, out_d = _timeit(lambda: dense_fn(x, qw.data, qw.scale), n=10)
    us_s, out_s = _timeit(lambda: skip_fn(x, sw.data, sw.scale, sw.idx),
                          n=10)
    # f32 round-off only: the skip arm sums the same nonzero products in
    # a different order over the 2048-deep contraction
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_s),
                               rtol=1e-4, atol=1e-3)
    speedup = us_d / us_s
    _row("sparse_matmul_speedup", us_s,
         f"dense_us={us_d:.1f};sparse_us={us_s:.1f};"
         f"speedup={speedup:.2f}x;target=1.5x;met={speedup >= 1.5};"
         f"shape=({M},{N},{K});spec=2:4:row")

    # ---- compressed panel DMA bytes (col/bitmask format) -------------
    bk = 128
    dense_bytes = (N // 2) * bk                     # int4 nibble panel
    sparse_bytes = (N // 2 // 2) * bk + (N // 8) * bk   # vals + bitmask
    _row("sparse_panel_bytes", 0.0,
         f"dense_bytes={dense_bytes};sparse_bytes={sparse_bytes};"
         f"reduction={1 - sparse_bytes / dense_bytes:.3f};spec=2:4;"
         f"bits_per_elem=3.0")

    # ---- bit-exactness of the kernel int-accumulation path -----------
    Mi, Ni, Ki = 8, 32, 16
    spc = SparsityConfig(2, 4, "col")
    wi = jnp.asarray(rng.standard_normal((Ni, Ki)), jnp.float32)
    xi = jnp.asarray(rng.integers(-8, 8, size=(Mi, Ni)), jnp.int8)
    xsc = jnp.asarray(rng.uniform(0.5, 2.0, size=(Mi, 1)), jnp.float32)
    qci = QuantConfig("w4a8", 16)
    swi = sparsify_weight(wi, qci, spc)
    wdi = wi * nm_prune_mask(wi, spc).astype(wi.dtype)
    qwi = quantize_weight(wdi, qci)

    def kern():
        return sm.sparse_fused_matmul(
            xi, swi.data, swi.scale, swi.idx, n=2, m=4, bits=4,
            x_scale=xsc, accum="int32", bm=Mi, bk=Ki, interpret=True)
    # the reference is the dense-mask reconstruction through the SAME
    # int-accumulation chain, jit-compiled (see int_group_matmul_ref's
    # docstring: bit-equality holds jit-vs-jit — both sides then share
    # one FMA contraction of the scale-combine)
    ref_fn = jax.jit(lambda a, d, s, i, xs: ref.sparse_fused_matmul_ref(
        a, d, s, i, n=2, m=4, bits=4, x_scale=xs, accum="int32"))
    us_k, out_k = _timeit(kern)
    out_r = ref_fn(xi, swi.data, swi.scale, swi.idx, xsc)
    exact = bool((np.asarray(out_k) == np.asarray(out_r)).all())
    _row("sparse_bitexact_int", us_k,
         f"bit_exact={exact};spec=2:4;accum=int32;"
         f"shape=({Mi},{Ni},{Ki})")

    # ---- scheduler-level: sparse vs dense-masked serving -------------
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.engine import prune_params, quantize_params
    from repro.serve.paged import Scheduler

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=256)
    params = api.init(jax.random.PRNGKey(0), cfg)
    scfg = cfg.replace(sparsity="2:4")
    sp_params = quantize_params(params, scfg)
    dm_params = quantize_params(prune_params(params, scfg), cfg)
    rngp = np.random.default_rng(1)
    reqs = [rngp.integers(1, cfg.vocab_size, size=ln).tolist()
            for ln in (8, 24, 16, 40, 8, 32)]
    new, max_len, bs = 6, 128, 16

    def run_sched(c, p):
        sch = Scheduler(c, p, slots=4, max_len=max_len, block_size=bs,
                        chunk=16)
        for i, pr in enumerate(reqs):
            sch.submit(Request(rid=i, prompt=pr, max_new=new))
        return sch.run()

    t0 = time.perf_counter()
    out_dm = run_sched(cfg, dm_params)
    t_dm = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_sp = run_sched(scfg, sp_params)
    t_sp = time.perf_counter() - t0
    ident = out_sp == out_dm
    toks = len(reqs) * new
    _row("sparse_sched_dense_masked", t_dm * 1e6,
         f"tok_s={toks / t_dm:.1f}")
    _row("sparse_sched_sparse", t_sp * 1e6,
         f"tok_s={toks / t_sp:.1f};tokens_identical={ident};spec=2:4")
    assert ident, "2:4-sparse scheduler output diverged from dense-masked"

    # ---- analytic RCW-CIM projections --------------------------------
    for gran in ("col", "row"):
        r = pm.sparsity_report(2, 4, gran)
        _row(f"sparse_model_{gran}", 0.0,
             f"weight_reduction={r['weight_reduction']:.3f};"
             f"dram_reduction={r['dram_reduction']:.3f};"
             f"update_reduction={r['update_reduction']:.3f};"
             f"decode_speedup={r['decode_speedup']:.2f}x;"
             f"prefill_speedup={r['prefill_speedup']:.2f}x;"
             f"sparse_tok_s={r['sparse_tokens_per_s']:.1f};"
             f"dense_tok_s={r['dense_tokens_per_s']:.1f}")


def bench_obs() -> None:
    """PR 10 rows (BENCH_pr10.json): serving telemetry (DESIGN.md §15).

    * ``obs_sched_off`` / ``obs_sched_on`` — the same skewed workload
      through the paged Scheduler with telemetry hard-off (the disabled
      no-op instruments) vs fully on (tracing + metrics). One scheduler
      per arm: warm run compiles, min-of-3 timed repeats measure. The
      acceptance gate: tracing every span of every request costs ≤5 %
      scheduler tok/s.
    * ``obs_trace_valid`` — the on-arm's Chrome-trace export must
      validate (proper nesting per lane, no orphan spans, one complete
      admit→finish lifecycle per request) and leave zero open spans.
    * ``obs_tokens_reconcile`` — ``tokens_emitted_total`` (and the
      Prometheus text round-trip of it) must EXACTLY equal the token
      count the scheduler returned, warmup included.
    * ``obs_census_decode`` — per-family dispatch counts from the §15
      unified ``Engine.dispatch_census``, folded into the export.
    * ``obs_drift_*`` — modeled-vs-measured report rows (decode/prefill
      s/token after platform-scale calibration)."""
    from repro import obs
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.batching import Request
    from repro.serve.engine import Engine, quantize_params
    from repro.serve.paged import Scheduler

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, quant_mode="w4a8", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=256)
    params = quantize_params(api.init(jax.random.PRNGKey(0), cfg), cfg)
    rngp = np.random.default_rng(2)
    reqs = [rngp.integers(1, cfg.vocab_size, size=ln).tolist()
            for ln in (8, 24, 16, 40, 8, 32)]
    new, max_len, bs, chunk = 16, 128, 16, 16

    def build(trace, metrics):
        sch = Scheduler(cfg, params, slots=4, max_len=max_len,
                        block_size=bs, chunk=chunk, trace=trace,
                        metrics=metrics)
        rid = [0]

        def go():
            for pr in reqs:
                sch.submit(Request(rid=rid[0], prompt=pr, max_new=new))
                rid[0] += 1
            t0 = time.perf_counter()
            sch.run()
            return time.perf_counter() - t0

        return sch, go

    sch_off, go_off = build(obs.Tracer(enabled=False),
                            obs.Metrics(enabled=False))
    trace, metrics = obs.Tracer(enabled=True), obs.Metrics(enabled=True)
    sch_on, go_on = build(trace, metrics)
    go_off()
    go_on()                                    # compile + cache warmup
    # paired, interleaved repeats: machine-load drift (this bench runs
    # last in the smoke suite) hits both arms alike, and min-of-N picks
    # each arm's cleanest run
    ts_off, ts_on = [], []
    for _ in range(5):
        ts_off.append(go_off())
        ts_on.append(go_on())
    t_off, t_on = min(ts_off), min(ts_on)
    toks = len(reqs) * new
    overhead = (t_on - t_off) / t_off * 100.0
    _row("obs_sched_off", t_off * 1e6, f"tok_s={toks / t_off:.1f}")
    _row("obs_sched_on", t_on * 1e6,
         f"tok_s={toks / t_on:.1f};overhead_pct={overhead:.2f};"
         f"target=5.0;met={overhead <= 5.0}")
    assert overhead <= 5.0, \
        f"telemetry overhead {overhead:.2f}% exceeds the 5% budget"

    # -- export validity + lifecycle completeness ----------------------
    doc = trace.export_chrome()
    counts = obs.validate_chrome_trace(doc)
    lives = obs.request_lifecycles(doc)
    _row("obs_trace_valid", 0.0,
         f"spans={counts['spans']};events={counts['events']};"
         f"lanes={counts['lanes']};lifecycles={len(lives)};"
         f"open_spans={trace.open_count};valid=True")
    assert trace.open_count == 0 and len(lives) == len(sch_on.done)

    # -- exact token reconciliation (incl. the Prometheus round-trip) --
    emitted = metrics.counter("tokens_emitted_total").value
    sched_toks = sum(len(v) for v in sch_on.done.values())
    samples = obs.parse_prometheus(metrics.export_prometheus())
    prom = samples["repro_tokens_emitted_total"]
    exact = emitted == sched_toks == prom
    _row("obs_tokens_reconcile", 0.0,
         f"metric={emitted:.0f};scheduler={sched_toks};prom={prom:.0f};"
         f"tokens_match={exact}")
    assert exact, (emitted, sched_toks, prom)

    # -- per-family dispatch census, folded into the export ------------
    eng = Engine(cfg, params, max_len=max_len)
    census = eng.dispatch_census("decode")
    obs.fold_census(metrics, census, "decode")
    _row("obs_census_decode", 0.0,
         f"total={census['total']};pallas_calls={census['pallas_call']};"
         f"dot_general={census['dot_general']}")

    # -- modeled-vs-measured drift -------------------------------------
    for r in obs.drift_report(metrics, chunk=chunk, ctx=max_len,
                              params=params):
        kap = f"{r['kappa']:.3g}" if r["kappa"] is not None else "none"
        _row(f"obs_drift_{r['name'].split()[0].strip('-')}", 0.0,
             f"measured={r['measured']:.3e};modeled={r['modeled']:.3e};"
             f"unit={r['unit']};drift_pct={r['drift_pct']:.1f};"
             f"kappa={kap}")


ALL_BENCHES = [bench_table1, bench_fig8, bench_fig9, bench_table2,
               bench_kernels, bench_fused, bench_decode_dispatch,
               bench_paged, bench_prefill, bench_spec, bench_shard,
               bench_sparse, bench_obs]


def run_benches(benches, keep_going: bool = False):
    """Shared row driver (also used by smoke.py, so the CSV/JSON shape
    lives in exactly one place). Returns names of groups that raised
    (``keep_going``) — or propagates the first failure."""
    import traceback
    print("name,us_per_call,derived")
    failures = []
    for bench in benches:
        try:
            bench()
        except Exception:
            if not keep_going:
                raise
            failures.append(bench.__name__)
            traceback.print_exc()
    return failures


def write_json(target=None) -> Path:
    target = Path(target) if target else BENCH_JSON
    target.write_text(json.dumps({"rows": _ROWS}, indent=2) + "\n")
    print(f"# wrote {target}")
    for prefix, tag, default in (("paged_", "pr5", PR5_JSON),
                                 ("prefill_", "pr6", PR6_JSON),
                                 ("spec_", "pr7", PR7_JSON),
                                 ("shard_", "pr8", PR8_JSON),
                                 ("sparse_", "pr9", PR9_JSON),
                                 ("obs_", "pr10", PR10_JSON)):
        rows = [r for r in _ROWS if r["name"].startswith(prefix)]
        if not rows or target == default:   # already the canonical artifact
            continue
        if target == BENCH_JSON:
            sub = default
        elif "pr3" in target.name:    # mirror redirects (e.g. fast mode)
            sub = target.with_name(target.name.replace("pr3", tag))
        else:
            sub = target.with_name(f"{tag}_" + target.name)
        sub.write_text(json.dumps({"rows": rows}, indent=2) + "\n")
        print(f"# wrote {sub}")
    return target


def main() -> None:
    run_benches(ALL_BENCHES)
    write_json()


if __name__ == "__main__":
    main()
