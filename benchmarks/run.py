"""Benchmark harness — one function per paper table/figure, plus kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  * table1_*  — Table I   dataflow access counts (llama2-7b GEMM set)
  * fig8a/b   — Fig 8     DRAM-access / CIM-update reductions
  * fig9a/b   — Fig 9     prefill / decode latency reductions
  * table2_*  — Table II  TOPS, TOPS/W, prefill ms, decode tok/s
  * kernel_*  — wall time of the jitted ops on CPU (indicative only; the
                graded perf story is the dry-run roofline analysis)

``us_per_call`` is the wall time of evaluating the row's underlying
function (analytic rows are effectively free); ``derived`` carries the
reproduced quantity and, where the paper publishes the same number, the
paper value for side-by-side comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import Dataflow, TileConfig, access_counts
from repro.core.quant import QuantConfig, quantize_weight
from repro.kernels import ref
from repro.sim import perf_model as pm


def _timeit(fn, n=3):
    out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6, out


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def bench_table1() -> None:
    """Table I: element access counts for each dataflow (representative
    4096x4096 GEMM, M=1024 tokens, 128/256/256 tiles)."""
    tc = TileConfig(M=1024, N=4096, K=4096, m=128, n=256, k=256)
    for df in Dataflow:
        us, c = _timeit(lambda df=df: access_counts(df, tc))
        _row(f"table1_{df.value}", us,
             f"in={c['input']};w={c['weight']};out={c['output']};"
             f"upd={c['cim_update']}")


def bench_fig8() -> None:
    us, r = _timeit(pm.fig8a_dram_reduction)
    _row("fig8a_dram_reduction", us,
         f"repro={r['reduction']:.3f};paper={r['paper']}")
    us, r = _timeit(pm.fig8b_update_reduction)
    _row("fig8b_update_reduction", us,
         f"repro={r['reduction']:.3f};paper={r['paper']}")


def bench_fig9() -> None:
    us, r = _timeit(pm.fig9a_prefill_reduction)
    _row("fig9a_prefill_reduction", us,
         f"repro={r['reduction']:.4f};paper={r['paper']};"
         f"per_token_ms={r['per_token_ms']:.2f};paper_ms=4.2")
    us, r = _timeit(pm.fig9b_decode_reductions)
    _row("fig9b_rcw_reduction", us,
         f"repro={r['rcw_reduction']:.4f};paper={r['paper_rcw']}")
    _row("fig9b_fusion_reduction", 0.0,
         f"repro={r['fusion_reduction']:.4f};paper={r['paper_fusion']}")
    _row("fig9b_total_reduction", 0.0,
         f"repro={r['total_reduction']:.4f};paper={r['paper_total']}")
    _row("fig9b_decode_tokens_per_s", 0.0,
         f"repro={r['tokens_per_s']:.2f};paper={r['paper_tokens_per_s']}")


def bench_table2() -> None:
    us, t = _timeit(pm.table2_summary)
    _row("table2_throughput_tops", us,
         f"repro={t['throughput_tops']:.2f};paper={t['paper_tops']}")
    _row("table2_energy_eff", 0.0,
         f"repro={t['energy_eff_tops_per_w']};paper={t['paper_tops_per_w']}")
    _row("table2_prefill_ms", 0.0,
         f"repro={t['prefill_per_token_ms']:.2f};paper=4.2")
    _row("table2_decode_tok_s", 0.0,
         f"repro={t['decode_tokens_per_s']:.2f};paper=26.87")
    _row("table2_energy_per_token_mj", 0.0,
         f"repro={t['energy_per_token_mj']:.2f}")


def bench_kernels() -> None:
    """Jitted op wall-times on CPU (ref lowering path, as the dry-run
    lowers it off-TPU)."""
    rng = np.random.default_rng(0)
    M, N, K = 256, 1024, 1024
    w = jnp.asarray(rng.standard_normal((N, K)).astype(np.float32))
    qw = quantize_weight(w, QuantConfig("w4a8", 128))
    x = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))

    f = jax.jit(lambda x: ref.ws_ocs_matmul_ref(x, qw.data, qw.scale, bits=4))
    us, _ = _timeit(lambda: f(x), n=10)
    flops = 2 * M * N * K
    _row("kernel_w4a8_matmul_1024", us, f"gflops={flops/us/1e3:.1f}")

    xs = jnp.asarray(rng.standard_normal((64, 2048)).astype(np.float32))
    g = jax.jit(lambda x: ref.group_softmax_ref(x, 64))
    us, _ = _timeit(lambda: g(xs), n=10)
    _row("kernel_group_softmax_64x2048", us,
         f"gelem_s={64*2048/us/1e3:.2f}")

    gamma = jnp.ones(2048)
    h = jax.jit(lambda x: ref.group_rmsnorm_ref(x, gamma, 128))
    us, _ = _timeit(lambda: h(xs), n=10)
    _row("kernel_group_rmsnorm_64x2048", us,
         f"gelem_s={64*2048/us/1e3:.2f}")

    q = jnp.asarray(rng.standard_normal((1, 8, 256, 64)).astype(np.float32))
    kv = jnp.asarray(rng.standard_normal((1, 2, 256, 64)).astype(np.float32))
    a = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us, _ = _timeit(lambda: a(q, kv, kv), n=10)
    _row("kernel_attention_gqa_256", us, "oracle_path")


def main() -> None:
    print("name,us_per_call,derived")
    bench_table1()
    bench_fig8()
    bench_fig9()
    bench_table2()
    bench_kernels()


if __name__ == "__main__":
    main()
