"""jax version-compatibility shims (DESIGN.md §6).

The repo targets the current jax API surface; the pinned container runs
jax 0.4.37, which predates a few names the code uses. Policy (§6): all
version-sensitive jax APIs are accessed through this module (and
``repro.kernels.pallas_compat`` for Pallas-TPU names) — never through
``jax.*`` directly — so a jax upgrade is a one-file change and the repo
runs unmodified on both sides of each rename.

Covered here:

* ``jax.sharding.AxisType``            (added after 0.4.37)
* ``jax.make_mesh(..., axis_types=)``  (kwarg added after 0.4.37)
* ``jax.sharding.get_abstract_mesh``   (added after 0.4.37; the fallback
  reads the ambient physical mesh that ``with mesh:`` installs)
* ``jax.set_mesh``                     (added after 0.4.37; the fallback
  uses the Mesh object itself as the context manager)
"""
from __future__ import annotations

import enum

import jax

__all__ = ["AxisType", "make_mesh", "get_abstract_mesh", "set_mesh",
           "tree_flatten_with_path", "abstract_mesh", "cost_analysis"]


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a per-program *list* of dicts
    on 0.4.37 and a flat dict on current jax; normalize to the dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across the signature change:
    current jax takes ``(axis_sizes, axis_names)``, 0.4.37 takes a single
    ``((name, size), ...)`` tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def tree_flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` (added after 0.4.37); falls back to
    the long-stable ``jax.tree_util`` spelling."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType (all axes behave as Auto
        on 0.4.37, which is the only mode this repo uses)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version
    (silently dropped pre-0.4.38, where Auto was the only behavior)."""
    try:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, devices=devices)
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def get_abstract_mesh():
    """The ambient mesh, or None when outside any mesh context. Callers
    treat None and an empty mesh identically (no-op constraints)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh. On
    0.4.37 the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
