"""Draft providers for speculative decoding (DESIGN.md §12).

The paged scheduler's speculative tick multiplies tokens per
weight-stream pass: a cheap *draft* proposes K tokens per sequence, the
target model scores all K+1 positions (the pending token plus the K
drafts) in ONE paged chunk dispatch — ``api.verify_step``, which routes
through the same offset-causal ``ops.paged_flash_prefill`` path as
chunked prefill — and greedy acceptance keeps the longest draft prefix
that matches the target's own argmax chain, plus the target's bonus
token. Rejection is a block-table truncation: rejected positions hold
stale K/V that the next verify chunk overwrites before any read, so no
KV is ever rewritten on rollback.

Exactness does NOT depend on the draft: every emitted token is either a
draft the target itself would have produced greedily or the target's
own argmax, so ANY ``DraftProvider`` yields token-identical greedy
output versus the non-speculative engine — the draft only moves the
acceptance rate (and therefore the speedup). Two providers:

* ``ModelDraft`` — a real draft model (typically a smaller config)
  decoding greedily against its own dense KV cache, resynced to the
  accepted sequence each pass. ``draft_cfg == target_cfg`` gives
  acceptance 1.0 and is the token-identity anchor in tests.
* ``OracleDraft`` — a measurement device for benchmarks: drafts the
  known greedy continuation, deterministically corrupted per position
  so acceptance averages a chosen rate. Zero draft cost, so BENCH_pr7's
  tok/s-vs-acceptance sweep isolates the verify-path economics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class SpecConfig:
    """Scheduler speculation knobs: ``draft`` is any DraftProvider,
    ``k`` the number of drafted tokens verified per pass (the verify
    chunk is k+1 wide)."""
    draft: "DraftProvider"
    k: int = 4


class DraftProvider:
    """Interface: ``draft(key, tokens, k)`` returns k proposed
    continuation tokens for the sequence ``tokens`` (prompt + all
    accepted/emitted tokens so far); ``key`` identifies the sequence
    (stable across passes, unique per beam fork). ``release(key)``
    drops any per-sequence state when the sequence finishes or is
    preempted."""

    def draft(self, key: Hashable, tokens: Sequence[int],
              k: int) -> List[int]:
        raise NotImplementedError

    def release(self, key: Hashable) -> None:      # pragma: no cover
        pass


def accept_length(drafts: Sequence[int], target: Sequence[int]) -> int:
    """Greedy acceptance: the longest prefix of ``drafts`` matching the
    target's argmax chain ``target`` (target[i] is the target's next
    token after drafts[:i])."""
    a = 0
    for d, t in zip(drafts, target):
        if d != t:
            break
        a += 1
    return a


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class ModelDraft(DraftProvider):
    """Greedy draft model over a private dense KV cache per sequence.

    Each pass feeds the tokens the sequence gained since the last sync
    (accepted drafts + the target's bonus token) through single-token
    decode steps, then drafts ``k`` tokens greedily. Drafted tokens are
    fed back (their K/V lands at positions past the synced length), but
    the synced length only advances over *accepted* tokens — the next
    pass rewrites the speculative positions before anything reads them,
    the same overwrite-before-read invariant the target's paged verify
    relies on.

    The first call for a key prefills the whole sequence, padded to a
    power-of-two bucket (one jit per bucket, the ContinuousBatcher
    idiom); the bucket-padded last-row logits are inexact, so the last
    real token is re-decoded at its true position — identical K/V,
    exact logits."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 trace=None):
        from repro import obs
        self.cfg, self.params, self.max_len = cfg, params, max_len
        # §15: draft-model work gets its own spans (nested inside the
        # scheduler's "draft" span); None → the env-gated default tracer
        self.trace = trace if trace is not None else obs.default_tracer()
        self._state: Dict[Hashable, Tuple[object, int, jax.Array]] = {}
        self._decode = jax.jit(
            lambda p, t, c, i: api.serve_step(p, cfg, t, c, i))
        self._prefills: Dict[int, object] = {}

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg = self.cfg
            self._prefills[bucket] = jax.jit(
                lambda p, t, c: api.prefill_step(p, cfg, {"tokens": t}, c))
        return self._prefills[bucket]

    def _sync(self, key: Hashable, tokens: Sequence[int]):
        """Bring the key's cache up to ``tokens``; returns (cache, m,
        logits) with logits predicting token m (m == len(tokens))."""
        state = self._state.get(key)
        if state is None:
            n = len(tokens)
            bucket = _bucket(n)
            buf = np.zeros((1, bucket), np.int32)
            buf[0, :n] = tokens
            cache = api.init_cache(self.cfg, 1, self.max_len)
            _, cache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(buf), cache)
            # bucket padding poisons the last-row logits and the K/V
            # past n; re-decode the last real token at n-1 for both
            logits, cache = self._decode(
                self.params, jnp.asarray([[tokens[-1]]], jnp.int32),
                cache, jnp.asarray(n - 1, jnp.int32))
            return cache, n, logits
        cache, m, logits = state
        for p in range(m, len(tokens)):
            logits, cache = self._decode(
                self.params, jnp.asarray([[tokens[p]]], jnp.int32),
                cache, jnp.asarray(p, jnp.int32))
        return cache, len(tokens), logits

    def draft(self, key: Hashable, tokens: Sequence[int],
              k: int) -> List[int]:
        with self.trace.span("draft_model", k=k):
            cache, m, logits = self._sync(key, tokens)
            out: List[int] = []
            for j in range(k):
                tok = int(jnp.argmax(logits[0]))
                out.append(tok)
                if j < k - 1:                   # last draft's K/V unused
                    logits, cache = self._decode(
                        self.params, jnp.asarray([[tok]], jnp.int32),
                        cache, jnp.asarray(m + j, jnp.int32))
            # speculative K/V past m is rewritten on the next sync
            self._state[key] = (cache, m, logits)
        return out

    def release(self, key: Hashable) -> None:
        self._state.pop(key, None)


class OracleDraft(DraftProvider):
    """Scripted drafts with a dialable acceptance rate (bench/test
    device — no model runs, so draft cost is ~zero).

    ``sequences`` maps each key to the full greedy reference sequence
    (prompt + reference continuation). Each drafted position is the
    reference token, corrupted to a guaranteed-wrong token with
    probability ``1 - accept_rate`` — decided by a counter-based RNG on
    (seed, key, position), so the acceptance pattern is a deterministic
    property of the position, independent of how passes land on it.
    Positions past the reference draft a wrong-by-construction token
    (the sequence is about to finish anyway)."""

    def __init__(self, sequences: Dict[Hashable, Sequence[int]],
                 accept_rate: float = 1.0, seed: int = 0,
                 vocab_size: int = 1 << 30):
        self.sequences = {k: list(v) for k, v in sequences.items()}
        self.accept_rate = float(accept_rate)
        self.seed = seed
        self.vocab_size = vocab_size

    def _corrupt(self, tok: int, key: Hashable, pos: int) -> int:
        # seed from raw ints where possible: Python's hash() is
        # per-process randomized, which would unseat bench reproducibility
        parts = key if isinstance(key, tuple) else (key,)
        ints = [p for p in parts if isinstance(p, int)] or [abs(hash(key))]
        rng = np.random.default_rng([self.seed, pos] + ints)
        if rng.random() < self.accept_rate:
            return tok
        return int((tok + 1 + rng.integers(self.vocab_size - 1))
                   % self.vocab_size)

    def draft(self, key: Hashable, tokens: Sequence[int],
              k: int) -> List[int]:
        full = self.sequences[key]
        pos = len(tokens)
        out = []
        for j in range(k):
            p = pos + j
            ref = full[p] if p < len(full) else 0
            tok = self._corrupt(ref, key, p) if p < len(full) \
                else (ref + 1) % self.vocab_size
            out.append(tok)
        return out
