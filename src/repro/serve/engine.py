"""Batched serving engine: prefill + decode with KV caches, greedy /
temperature sampling, and the paper's deployment configuration (W4A8
weights through the WS-OCS kernel path, LUT group-softmax, fused norms).

``quantize_params`` converts every 2-D linear weight into the serving
QuantizedWeight dict that ``layers.apply_linear`` routes through
``ops.ws_ocs_matmul`` — the INT4 weight-streaming pipeline the paper
builds silicon for.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.quant import (QuantConfig, SparsityConfig, nm_prune_mask,
                              parse_sparsity, quantize_weight, sparse_ok,
                              sparsify_weight)
from repro.models import api
from repro.models.layers import is_axes_leaf
# the jaxpr walk moved to repro.obs.census (DESIGN.md §15); re-exported
# so the long-standing ``engine.count_eqns`` import path keeps working —
# new code should import from ``repro.obs`` and use ``dispatch_census``
from repro.obs.census import _subjaxprs, census_jaxpr, count_eqns  # noqa: F401


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 → greedy
    seed: int = 0


def _quantize_one(w, qc: QuantConfig,
                  sp: Optional[SparsityConfig] = None) -> Dict:
    if sp is not None and sparse_ok(w.shape[0], sp):
        sw = sparsify_weight(w, qc, sp)
        # n/m ride in the KEY name (static under vmap/scan); granularity
        # is recovered from the metadata leaf's ndim in layers.py
        return {"q": sw.data, "scale": sw.scale, sp.key: sw.idx}
    qw = quantize_weight(w, qc)
    return {"q": qw.data, "scale": qw.scale}


def quantize_params(params: Dict, cfg: ModelConfig,
                    axes: Optional[Dict] = None) -> Dict:
    """Quantize every matmul weight (leaves named 'w': plain 2-D or
    layer-stacked 3-D) per cfg.quant_mode. The bit-width travels in the
    dtype (uint8 = nibble-packed INT4, int8 = INT8) so the quantized dict
    scans cleanly over layers. Norm scales / biases / embeddings stay
    high precision (the paper keeps nonlinear paths FP16).

    ``cfg.sparsity`` ("2:4" / "n:m:row", §14) additionally prunes and
    compresses every eligible weight to structured N:M storage — pruning
    happens BEFORE quantization on the dense float weight, so the stored
    codes and scales are bit-identical to quantizing the masked dense
    weight (see ``prune_params``) and serving stays token-identical to
    the dense-masked equivalent checkpoint. Ineligible shapes (partial
    m-groups / non-byte-aligned bitmask rows) quantize dense as before."""
    if cfg.quant_mode == "bf16":
        return params
    qc = QuantConfig(cfg.quant_mode, cfg.quant_group)
    sp = parse_sparsity(cfg.sparsity)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and hasattr(v, "ndim") and v.ndim == 2 \
                        and v.shape[0] % 2 == 0:
                    out[k] = _quantize_one(v, qc, sp)
                elif k == "w" and hasattr(v, "ndim") and v.ndim == 3 \
                        and v.shape[1] % 2 == 0:
                    out[k] = jax.vmap(lambda w2: _quantize_one(w2, qc, sp))(v)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def prune_params(params: Dict, cfg: ModelConfig) -> Dict:
    """Dense-masked equivalent of ``cfg.sparsity``: magnitude-prune the
    SAME leaves ``quantize_params`` would compress, but keep them dense
    (weights multiplied by the N:M keep-mask). Quantizing the result
    with ``cfg.replace(sparsity="")`` yields the dense-masked checkpoint
    a sparse one must serve token-identically to."""
    sp = parse_sparsity(cfg.sparsity)
    if sp is None or cfg.quant_mode == "bf16":
        return params

    def prune(w):
        return w * nm_prune_mask(w, sp).astype(w.dtype)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and hasattr(v, "ndim") and v.ndim == 2 \
                        and v.shape[0] % 2 == 0 and sparse_ok(v.shape[0], sp):
                    out[k] = prune(v)
                elif k == "w" and hasattr(v, "ndim") and v.ndim == 3 \
                        and v.shape[1] % 2 == 0 and sparse_ok(v.shape[1], sp):
                    out[k] = jax.vmap(prune)(v)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


class Engine:
    def __init__(self, cfg: ModelConfig, params: Dict, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._dec_jaxprs: Dict[int, object] = {}
        self._pref_jaxprs: Dict[tuple, object] = {}
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill_step(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, i: api.serve_step(p, cfg, t, c, i))

    def _decode_jaxpr(self, batch: int):
        """Decode-step jaxpr, traced once per batch size (tracing the
        scanned model costs seconds; counting it is cheap). Cached under
        the kernel-dispatch mode active at first call — toggle
        ``ops.force_pallas`` before the first count, not between."""
        if batch not in self._dec_jaxprs:
            cache = api.init_cache(self.cfg, batch, self.max_len)
            tok = jnp.zeros((batch, 1), jnp.int32)
            self._dec_jaxprs[batch] = jax.make_jaxpr(
                lambda p, t, c, i: api.serve_step(p, self.cfg, t, c, i))(
                self.params, tok, cache, jnp.asarray(0, jnp.int32))
        return self._dec_jaxprs[batch]

    def decode_eqn_count(self, batch: int = 1,
                         primitive: Optional[str] = None) -> int:
        """Op dispatches (jaxpr equations before XLA fusion) issued by
        one decode step — the fused-vs-unfused metric of DESIGN.md §7,
        reported in BENCH_pr3.json. ``primitive="pallas_call"`` counts
        kernel launches only."""
        return count_eqns(self._decode_jaxpr(batch).jaxpr, primitive)

    def _prefill_jaxpr(self, batch: int, chunk: int, block_size: int):
        """Chunked-prefill-step jaxpr over a paged cache (same caching
        caveats as ``_decode_jaxpr``: traced once per shape, under the
        kernel-dispatch mode active at first call)."""
        key = (batch, chunk, block_size)
        if key not in self._pref_jaxprs:
            nb = batch * (self.max_len // block_size) + 1
            cache = api.init_cache(self.cfg, batch, self.max_len,
                                   num_blocks=nb, block_size=block_size)
            tok = jnp.zeros((batch, chunk), jnp.int32)
            start = jnp.zeros((batch,), jnp.int32)
            self._pref_jaxprs[key] = jax.make_jaxpr(
                lambda p, t, c, s: api.prefill_chunk_step(
                    p, self.cfg, {"tokens": t}, c, s))(
                self.params, tok, cache, start)
        return self._pref_jaxprs[key]

    def prefill_eqn_count(self, batch: int = 1, chunk: int = 32,
                          block_size: int = 16,
                          primitive: Optional[str] = None) -> int:
        """Op dispatches issued by one chunked-prefill tick — the prefill
        mirror of ``decode_eqn_count`` (ROADMAP item 3's kernel-residency
        metric, reported in BENCH_pr6.json). ``primitive="pallas_call"``
        counts kernel launches; ``primitive="dot_general"`` counts the
        matmuls that escaped the kernel family — on the kernel path with
        quantized weights this must be exactly the LM head (attention and
        every layer matmul stay Pallas-resident, DESIGN.md §11)."""
        return count_eqns(
            self._prefill_jaxpr(batch, chunk, block_size).jaxpr, primitive)

    def dispatch_census(self, phase: str = "decode", batch: int = 1,
                        chunk: int = 32, k: int = 4,
                        block_size: int = 16) -> Dict[str, int]:
        """Multi-primitive census of one serving step (the §15 unified
        front door over the three ``*_eqn_count`` wrappers): phase ∈
        {"decode", "prefill", "verify"} → {"total", "pallas_call",
        "dot_general"} dispatch counts from the cached per-shape jaxpr.
        For arbitrary callables use ``repro.obs.dispatch_census``."""
        if phase == "decode":
            jx = self._decode_jaxpr(batch)
        elif phase == "prefill":
            jx = self._prefill_jaxpr(batch, chunk, block_size)
        elif phase == "verify":
            jx = self._prefill_jaxpr(batch, k + 1, block_size)
        else:
            raise ValueError(f"unknown phase {phase!r}")
        return census_jaxpr(jx)

    def verify_eqn_count(self, batch: int = 1, k: int = 4,
                         block_size: int = 16,
                         primitive: Optional[str] = None) -> int:
        """Op dispatches issued by one speculative-verify pass
        (``api.verify_step`` over k drafts — structurally a
        chunked-prefill step with chunk = k+1, DESIGN.md §12). The spec
        path's economics rest on this count being flat in k: one pass
        scores k+1 positions through the same dispatch schedule a
        one-token decode would cost on the prefill path, so accepted
        drafts multiply tokens per dispatch instead of adding
        dispatches."""
        return self.prefill_eqn_count(batch=batch, chunk=k + 1,
                                      block_size=block_size,
                                      primitive=primitive)

    def generate(self, tokens: np.ndarray, sc: ServeConfig,
                 extra_batch: Optional[Dict] = None) -> np.ndarray:
        """tokens (B, S_prompt) int32 → (B, S_prompt + max_new) int32."""
        B, S = tokens.shape
        cache = api.init_cache(self.cfg, B, self.max_len)
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_batch:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch.items()})
        logits, cache = self._prefill(self.params, batch, cache)

        rng = jax.random.PRNGKey(sc.seed)
        out = [jnp.asarray(tokens)]
        pos0 = S + (self.cfg.vision_patches
                    if self.cfg.family == "vlm" and "vision_embeds" in batch
                    else 0)
        tok = self._sample(logits, rng, sc, 0)
        for i in range(sc.max_new_tokens):
            out.append(tok)
            if i == sc.max_new_tokens - 1:
                break
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos0 + i, jnp.int32))
            tok = self._sample(logits, rng, sc, i + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits: jax.Array, rng, sc: ServeConfig, i: int):
        if sc.temperature <= 0.0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        key = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            key, logits / sc.temperature, -1)[:, None].astype(jnp.int32)
