"""Continuous batching for the decode loop (dense/MoE/VLM families).

A fixed pool of ``slots`` shares one jitted decode step: every tick all
slots decode one token at their own positions (per-slot cache indices —
``layers.apply_attention`` supports a (B,) cache_index vector); finished
slots are evicted and refilled from the queue by prefilling the new
request into the slot's cache slice. Prompt prefills are padded to
power-of-two buckets so the prefill jit cache stays small.

This is the serving-throughput substrate the paper's decode economics
assume: the weight stream (the RCW-bound term) is amortized over every
active slot in the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    # n-best parallel sampling (paged Scheduler only, DESIGN.md §12):
    # after prefill the sequence forks into n_best slots — rank r
    # greedily continues the r-th best first token — sharing the prompt
    # KV copy-on-write. The dense ContinuousBatcher ignores it (>1
    # raises at submit). done[rid] becomes a list of n_best outputs.
    n_best: int = 1


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0              # next cache write position
    remaining: int = 0
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.rid >= 0


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 512):
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = slots, max_len
        self.cache = api.init_cache(cfg, slots, max_len)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._req_eos: Dict[int, Optional[int]] = {}

        self._decode = jax.jit(
            lambda p, t, c, i: api.serve_step(p, cfg, t, c, i))
        self._prefills = {}   # bucket → jitted single-slot prefill

    # -- public API ----------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.n_best == 1, \
            "n-best sampling needs the paged Scheduler (COW forking)"
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue and slots drain; returns rid → generated."""
        for _ in range(max_ticks):
            self._admit()
            if not any(s.active for s in self.slots):
                if not self.queue:
                    break
                continue
            self._tick()
        return self.done

    # -- internals -------------------------------------------------------
    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg = self.cfg

            def f(params, toks, cache1):
                return api.prefill_step(params, cfg, {"tokens": toks},
                                        cache1)

            self._prefills[bucket] = jax.jit(f)
        return self._prefills[bucket]

    def _admit(self) -> None:
        for si, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            n = len(req.prompt)
            bucket = _bucket(n)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt
            cache1 = api.init_cache(self.cfg, 1, self.max_len)
            logits, cache1 = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks), cache1)
            # bucket padding wrote junk K/V beyond n — harmless: the
            # per-slot validity mask stops at slot.pos (asserted by the
            # cache-poisoning test in tests/test_batching.py)
            # copy the slot cache slice in (batch dim = 1 in cache1)
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                    big, one.astype(big.dtype), si, self._batch_axis(big)),
                self.cache, cache1)
            # ONE exact first-token path for every prompt length: the
            # bucket-padded prefill logits row is only exact when
            # n == bucket, so the first generated token always comes from
            # re-decoding the last prompt token at position n-1 (its K/V
            # write recomputes identical values; prefill logits unused)
            slot.rid, slot.out = req.rid, []
            slot.remaining = req.max_new
            self._req_eos[req.rid] = req.eos
            slot.pos = n - 1
            tok = np.array(self.tokens)
            tok[si, 0] = req.prompt[-1]
            self.tokens = jnp.asarray(tok)

    def _batch_axis(self, leaf) -> int:
        # cache leaves are (L, B, ...) — batch axis 1
        return 1

    def _tick(self) -> None:
        pos = jnp.asarray([s.pos if s.active else 0 for s in self.slots],
                          jnp.int32)
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache, pos)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for si, slot in enumerate(self.slots):
            if slot.active:
                slot.pos += 1
                self._emit(si, int(nxt[si]))

    def _emit(self, si: int, tok: int) -> None:
        slot = self.slots[si]
        slot.out.append(tok)
        slot.remaining -= 1
        eos = self._req_eos.get(slot.rid)
        if slot.remaining <= 0 or (eos is not None and tok == eos):
            self.done[slot.rid] = slot.out
            self.slots[si] = _Slot()
            t = np.array(self.tokens)
            t[si, 0] = 0
            self.tokens = jnp.asarray(t)
        else:
            t = np.array(self.tokens)
            t[si, 0] = tok
            self.tokens = jnp.asarray(t)
