"""Paged-KV continuous-batching scheduler (DESIGN.md §10–§12).

Replaces the dense slot loop of ``serve.batching.ContinuousBatcher``:

* **Admission by free-block budget** — a request is admitted when the
  pool can cover its prompt blocks (minus any prefix-cache hits) plus
  one block of decode headroom per eventual fork; admission is FIFO, no
  head-of-line skip. An ``n_best > 1`` request additionally reserves
  ``n_best`` slots up front (holds) so the post-prefill fork always has
  somewhere to land.
* **Chunked prefill** — prompts stream into the pool ``chunk`` tokens
  per tick, interleaved with decode ticks of the already-running slots,
  through one fixed-shape jitted chunk step (the last chunk is padded;
  the first generated token is read from the last *real* row of the
  full-chunk logits, so there is no power-of-two bucket padding and no
  re-decode-the-last-prompt-token hack).
* **Prefix sharing** — full prompt blocks are content-hashed; a new
  request retains matching cached blocks instead of recomputing them
  (capped at (n-1)//BS blocks so the block holding the last prompt
  token — whose logits seed decode — is always privately recomputed and
  shared blocks are never written).
* **Copy-on-write beam forking (§12)** — an ``n_best > 1`` request
  forks its block table after prefill (``KVBlockPool.fork``: refcount
  bumps, zero KV copied); fork rank r greedily continues the r-th best
  first token. The forks share every prompt block until a fork's first
  decode write touches the shared partial tail block, which
  copy-on-writes THAT block only (``_ensure_capacity``), so n-best KV
  grows by the generated tail per fork, not a full prefix per fork.
  ``done[rid]`` is the rank-ordered list of outputs; each fork
  bit-matches an independently-prefilled greedy run seeded with its
  first token.
* **Speculative decoding (§12)** — with ``spec=SpecConfig(draft, k)``
  the decode tick becomes a draft+verify pass: the draft provider
  proposes k tokens per live slot and the target scores all k+1
  positions ([pending token, drafts]) in ONE ``api.verify_step``
  dispatch — structurally a chunked-prefill step, so attention runs
  through the same offset-causal ``ops.paged_flash_prefill`` kernel and
  the weight stream is paid once per pass instead of once per token.
  Greedy acceptance keeps the longest draft prefix matching the
  target's own argmax chain plus the target's bonus token; rollback is
  a block-table truncation (``_truncate``) — rejected positions hold
  stale K/V that the next pass overwrites before any read, so no KV is
  rewritten. Any draft yields token-identical greedy output; the draft
  only moves the acceptance rate (``spec_report``).
* **Preemption by eviction** — when the pool runs dry mid-decode the
  youngest running request is evicted (blocks released, request
  re-queued at the front); greedy decoding makes the later re-run
  token-identical, so preemption trades recompute for memory, never
  correctness. A beam group is evicted as a unit and replayed from
  scratch (deterministic forking makes the replay identical).

Exactness: every tick runs the same model step functions as the dense
engine over the same masked shapes (virtual length NBMAX·BS == the
dense engine's max_len), so greedy outputs are token-identical to
``Engine.generate`` — asserted across dense/MoE/VLM in
tests/test_paged.py and tests/test_spec_decode.py. Caveat: on the
Pallas kernel path (TPU / force_pallas) with ``use_lut_softmax=True``
the paged kernel caps the softmax group at the block size while the
dense kernel uses ``cfg.softmax_group``; LUT grouping is
numerics-visible, so kernel-path LUT serving agrees with the dense
engine only to LUT tolerance, not token-identically (exact-exp mode and
the off-TPU ref path are unaffected — DESIGN.md §10). MoE verify
chunks group k+1 tokens per slot, so the §10 capacity caveat applies to
speculative decode the same way it applies to chunked prefill.

The per-tick decode-active counts feed the WS-OCS weight-stream
amortization model (``sim.perf_model.scheduler_amortization_report``):
the RCW-bound weight stream is paid once per tick and divided by the
number of active decode slots — the denominator this subsystem exists
to keep high. Speculation multiplies the numerator instead: one stream
pass emits ``accepted + 1`` tokens per slot (``tick_emitted``,
modeled by ``sim.perf_model.speculative_decode_latency``).

Since PR 6 the chunk step's attention consumes the block table
*directly*: ``models.layers`` routes it to ``ops.paged_flash_prefill``,
whose Pallas kernel gathers K/V pool blocks through a scalar-prefetched
table (DESIGN.md §11) — and since PR 7 the speculative verify step
rides the same kernel path with S = k+1.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from collections import deque

from repro import obs
from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve.batching import Request
from repro.serve.paged.block_pool import KVBlockPool, prefix_hashes
from repro.serve.spec_decode import SpecConfig, accept_length

# slot/length-count histogram buckets (tick_active, accepted drafts)
_COUNT_BUCKETS = (0.0, 1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)


@dataclasses.dataclass
class _Entry:
    """Queue entry: the request plus tokens already emitted before a
    preemption (greedy decode resumes exactly by prefilling them).
    ``replays`` counts preemptions survived — telemetry marks replayed
    admissions so TTFT is only measured on the first attempt."""
    req: Request
    pre_out: List[int] = dataclasses.field(default_factory=list)
    replays: int = 0

    @property
    def tokens(self) -> List[int]:
        return list(self.req.prompt) + self.pre_out


@dataclasses.dataclass
class _Seq:
    entry: _Entry
    table: List[int]                  # physical block ids, logical order
    n_shared: int                     # leading blocks retained from cache
    pos: int                          # next cache write position
    phase: str                        # "prefill" | "decode"
    ticket: int                       # admission order (preemption prio)
    rank: int = 0                     # beam fork rank (0 = prefill root)
    out: List[int] = dataclasses.field(default_factory=list)
    t_emit: float = 0.0               # last emit time (inter-token metric)

    @property
    def rid(self) -> int:
        return self.entry.req.rid

    @property
    def emitted(self) -> int:
        return len(self.entry.pre_out) + len(self.out)


@dataclasses.dataclass
class _Hold:
    """Slot reserved for a beam fork while its root is still
    prefilling; filled by ``_prefill_tick`` at prompt completion."""
    rid: int


class Scheduler:
    """Drives dense/MoE/VLM decode over a paged KV pool. ``num_blocks``
    includes the reserved null block; it must be at least
    max_len//block_size + 2 so a lone request can always run. Pass
    ``spec=SpecConfig(draft, k)`` to replace the one-token decode tick
    with a k-draft speculative verify pass (DESIGN.md §12).

    Multi-device (DESIGN.md §13): with ``mesh`` set (a ("data","model")
    ``launch.mesh`` serving mesh) the K/V pools are sharded over "data"
    on the kv_heads dim — block ids stay global, so ALL host-side pool
    bookkeeping below is mesh-oblivious — params and block tables are
    replicated, and the step jits pin in/out shardings so the pools
    never silently gather. Per-device KV bytes shrink by the data-axis
    size while outputs stay token-identical to the single-device engine
    (no contraction dim is ever sharded; see
    ``parallel.sharding.PAGED_SERVE_RULES``).

    ``handoff`` (disaggregated prefill, §13): a callback
    ``handoff(sched, slot, seq, first_token)`` invoked INSTEAD of local
    decode when a single-stream request finishes prefill — the callback
    owns the sequence from here (gather the KV payload via
    ``gather_blocks``, free the slot with ``_release_slot``, and hand
    the request to a decode-pool scheduler's ``adopt``)."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 512, block_size: int = 16,
                 num_blocks: Optional[int] = None, chunk: int = 32,
                 prefix_cache: bool = True,
                 spec: Optional[SpecConfig] = None,
                 mesh=None,
                 handoff: Optional[Callable] = None,
                 trace: Optional[obs.Tracer] = None,
                 metrics: Optional[obs.Metrics] = None):
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg, self.params = cfg, params
        # telemetry (DESIGN.md §15): None → the env-gated process
        # defaults (REPRO_TRACE / REPRO_METRICS; off = every call a
        # no-op). Tests and benches pass their own enabled instances.
        self.trace = trace if trace is not None else obs.default_tracer()
        self.metrics = metrics if metrics is not None \
            else obs.default_metrics()
        self._req_span: Dict[int, int] = {}     # rid → open root handle
        self._admit_t: Dict[int, float] = {}    # rid → admit time (TTFT)
        self.n_slots, self.max_len = slots, max_len
        self.block_size, self.chunk = block_size, chunk
        self.nbmax = max_len // block_size
        if num_blocks is None:                       # dense-equivalent
            num_blocks = max(slots * self.nbmax + 1, self.nbmax + 2)
        assert num_blocks >= self.nbmax + 2, \
            f"pool too small: {num_blocks} < {self.nbmax + 2}"
        self.pool = KVBlockPool(num_blocks, block_size)
        self.prefix_cache = prefix_cache
        self.spec = spec
        self.mesh = mesh
        self.handoff = handoff

        cache = api.init_cache(cfg, slots, max_len, num_blocks=num_blocks,
                               block_size=block_size, mesh=mesh)
        self.kv = {"k": cache["k"], "v": cache["v"]}   # (L, NB, BS, Hkv, D)
        self.num_layers = cache["k"].shape[0]

        self.queue: Deque[_Entry] = deque()
        self.slots: List[Union[_Seq, _Hold, None]] = [None] * slots
        self.done: Dict[int, List] = {}
        self._group_out: Dict[int, List[Optional[List[int]]]] = {}
        self.tokens = np.zeros((slots, 1), np.int32)
        self._ticket = 0
        self.tick_active: List[int] = []         # decode slots per tick
        self.tick_prefill: List[int] = []        # prefill chunk launches/tick
        self.tick_emitted: List[int] = []        # tokens emitted per tick
        self.spec_passes = 0                     # per-slot verify passes
        self.spec_drafted = 0
        self.spec_accepted = 0

        jit_kw: Dict = {}
        copy_kw: Dict = {"donate_argnums": 0}
        gather_kw: Dict = {}
        adopt_kw: Dict = {"donate_argnums": 0}
        if mesh is not None:
            from repro.parallel import sharding as shd
            rep = shd.replicated(mesh)
            self.params = jax.device_put(params, rep)
            pool_sh = self.kv["k"].sharding      # §13 paged placement
            self._pool_sh, self._rep = pool_sh, rep
            cache_sh = {"k": pool_sh, "v": pool_sh, "bt": rep}
            jit_kw = dict(in_shardings=(rep, rep, cache_sh, rep),
                          out_shardings=(rep, cache_sh))
            copy_kw.update(in_shardings=(pool_sh, rep, rep),
                           out_shardings=pool_sh)
            # handoff payload (L, nb, BS, Hkv, D): same rank as the pool,
            # so it reuses the pool's spec — each data shard of a block
            # moves to (or arrives from) its counterpart device
            pay_sh = jax.sharding.NamedSharding(mesh, pool_sh.spec)
            gather_kw = dict(in_shardings=(pool_sh, rep),
                             out_shardings=pay_sh)
            adopt_kw.update(in_shardings=(pool_sh, rep, pay_sh),
                            out_shardings=pool_sh)
        self._decode = jax.jit(
            lambda p, t, c, i: api.serve_step(p, cfg, t, c, i), **jit_kw)
        self._chunk = jax.jit(
            lambda p, t, c, s: api.prefill_chunk_step(
                p, cfg, {"tokens": t}, c, s), **jit_kw)
        if spec is not None:
            assert spec.k >= 1, spec.k
            self._verify = jax.jit(
                lambda p, t, c, s: api.verify_step(p, cfg, t, c, s),
                **jit_kw)
        # COW device copy: one pool row dst ← src across the layer axis
        # (donated so the pool is updated in place, not duplicated)
        self._blk_copy = jax.jit(
            lambda pool, dst, src: pool.at[:, dst].set(pool[:, src]),
            **copy_kw)
        # §13 handoff: gather a table's blocks / scatter an adopted payload
        self._blk_gather = jax.jit(lambda pool, ids: pool[:, ids],
                                   **gather_kw)
        self._adopt_copy = jax.jit(
            lambda pool, ids, blk: pool.at[:, ids].set(
                blk.astype(pool.dtype)), **adopt_kw)

    def _ctx(self):
        """Ambient-mesh context for every jit call: the §13 sharding
        constraints inside the model (``constrain_replicated``) resolve
        bare PartitionSpecs against the mesh installed here. nullcontext
        single-device — the trace then contains no constraints at all."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro import compat
        return compat.set_mesh(self.mesh)

    # -- public API ------------------------------------------------------
    def submit(self, req: Request) -> None:
        n = len(req.prompt)
        assert n >= 1 and n + req.max_new - 1 <= self.max_len, \
            (n, req.max_new, self.max_len)
        assert 1 <= req.n_best <= self.n_slots, (req.n_best, self.n_slots)
        # disaggregated prefill hands off single streams only: a beam
        # group forks AFTER prefill, which is exactly the work this
        # scheduler is giving away (§13)
        assert self.handoff is None or req.n_best == 1, req.n_best
        self.queue.append(_Entry(req))

    def run(self, max_ticks: int = 100_000) -> Dict[int, List]:
        """Drive until queue and slots drain; returns rid → generated
        (a flat token list, or a rank-ordered list of lists for
        ``n_best > 1`` requests)."""
        for _ in range(max_ticks):
            active = any(isinstance(s, _Seq) for s in self.slots)
            if not active and not self.queue:
                break
            self._admit()
            self._prefill_tick()
            if self.spec is not None:
                self._spec_tick()
            else:
                self._grow_or_preempt()
                self._decode_tick()
        self.fold_stats()
        return self.done

    # -- stats / memory accounting ---------------------------------------
    def reset_stats(self) -> None:
        """Zero every run counter — pool stats (incl. the occupancy
        high-water mark), per-tick traces, and speculation counters — so
        benchmark arms that reuse one scheduler for a warm-up pass and a
        timed pass report the timed pass only. Serving state (pool
        allocation, prefix cache, live slots) is untouched."""
        self.pool.reset_stats()
        self.tick_active = []
        self.tick_prefill = []
        self.tick_emitted = []
        self.spec_passes = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

    def fold_stats(self, labels: Optional[Dict] = None) -> None:
        """Fold the pool's cumulative counters/derived stats into the
        metrics registry as ``pool_*`` gauges (set, not incremented —
        repeated folds are idempotent). ``run`` folds automatically at
        drain; long-lived holders (DisaggScheduler, benches) call it
        before exporting, passing ``labels`` (e.g. {"pool": "prefill"})
        when several pools share one registry."""
        if not self.metrics.enabled:
            return
        for k, v in self.pool.stats.items():
            self.metrics.gauge(f"pool_{k}", labels).set(v)

    def data_shards(self) -> int:
        """How many devices each KV block is split across (the §13 "data"
        axis, via the realized pool sharding — 1 when unsharded)."""
        if self.mesh is None:
            return 1
        k = self.kv["k"]
        shard = k.sharding.shard_shape(k.shape)
        return int(np.prod(k.shape)) // int(np.prod(shard))

    def per_device_peak_blocks(self) -> float:
        """Peak per-device KV footprint in block-equivalents: every
        block id lives on every data shard at 1/data_shards size, so the
        bound per-device ≤ peak/data + 1 is exact by construction."""
        return self.pool.peak_in_use / self.data_shards()

    def kv_bytes_peak_per_device(self) -> float:
        return self.kv_bytes_peak() / self.data_shards()

    def _block_bytes(self) -> int:
        k = self.kv["k"]          # (L, NB, BS, Hkv, D)
        per_tok = int(np.prod(k.shape[3:])) * k.dtype.itemsize
        return 2 * self.num_layers * self.block_size * per_tok   # K + V

    def kv_bytes_peak(self) -> int:
        """Peak bytes of *referenced* KV blocks across the run."""
        return self.pool.peak_in_use * self._block_bytes()

    def kv_bytes_dense_equiv(self) -> int:
        """What the dense per-slot layout would have allocated."""
        return self.n_slots * self.nbmax * self._block_bytes()

    def stream_amortization_report(self) -> Dict[str, float]:
        from repro.sim.perf_model import scheduler_amortization_report
        return scheduler_amortization_report(self.tick_active,
                                             prefill_counts=self.tick_prefill)

    def spec_report(self) -> Dict[str, float]:
        """Realized speculation stats: per-pass acceptance and the
        tokens-per-weight-stream-pass multiplier the verify path buys
        (1.0 when speculation is off — every pass emits one token)."""
        passes = self.spec_passes
        return {
            "passes": passes,
            "drafted": self.spec_drafted,
            "accepted": self.spec_accepted,
            "accept_rate": (self.spec_accepted / self.spec_drafted)
            if self.spec_drafted else 0.0,
            "tokens_per_pass": ((self.spec_accepted + passes) / passes)
            if passes else 1.0,
            "cow_copies": self.pool.cow_copies,
        }

    # -- admission -------------------------------------------------------
    def _admit(self) -> None:
        while self.queue:
            entry = self.queue[0]
            nb = entry.req.n_best
            free = [si for si, s in enumerate(self.slots) if s is None]
            if len(free) < nb:
                return                            # FIFO: no queue skip
            toks = entry.tokens
            n = len(toks)
            shared = self.pool.match_prefix(toks) if self.prefix_cache \
                else []
            # the block holding the last prompt token is always private:
            # its logits row seeds decode and its tail keeps growing
            shared = shared[:(n - 1) // self.block_size]
            need = -(-n // self.block_size) - len(shared)
            # shared blocks sitting in the prefix cache count in num_free
            # (evictable) but retaining them consumes that allocatability;
            # decode headroom is one block per eventual fork
            cached_shared = sum(self.pool.is_cached(b) for b in shared)
            if self.pool.num_free - cached_shared < need + nb:
                return                            # FIFO: no queue skip
            self.queue.popleft()
            for bid in shared:
                self.pool.retain(bid)
            table = list(shared)
            ok = True
            for _ in range(need):
                bid = self.pool.alloc()
                if bid is None:                   # accounting drift guard
                    ok = False
                    break
                table.append(bid)
            if not ok:
                for b in table:
                    self.pool.release(b)
                self.queue.appendleft(entry)
                return
            si = free[0]
            self.slots[si] = _Seq(entry=entry, table=table,
                                  n_shared=len(shared),
                                  pos=len(shared) * self.block_size,
                                  phase="prefill", ticket=self._ticket)
            for hsi in free[1:nb]:
                self.slots[hsi] = _Hold(entry.req.rid)
            if nb > 1:
                self._group_out[entry.req.rid] = [None] * nb
            self._ticket += 1
            if self.trace.enabled or self.metrics.enabled:
                rid = entry.req.rid
                self._admit_t[rid] = time.perf_counter()
                self._req_span[rid] = self.trace.begin(
                    "request", tid=obs.request_tid(rid), rid=rid,
                    prompt=n, n_best=nb, replays=entry.replays)
                self.trace.event("admit", tid=obs.request_tid(rid))
                self.metrics.counter("requests_admitted_total").inc()
                if entry.replays:
                    self.metrics.counter("requests_replayed_total").inc()

    # -- chunked prefill -------------------------------------------------
    def _bt_row(self, seq: Optional[_Seq]) -> np.ndarray:
        row = np.zeros(self.nbmax, np.int32)
        if seq is not None:
            row[:len(seq.table)] = seq.table
        return row

    def _layered_bt(self, bt: np.ndarray) -> jnp.ndarray:
        """(B, NBMAX) → (L, B, NBMAX): one logical table broadcast over
        the layer axis so the layer scan threads it (DESIGN.md §10)."""
        return jnp.asarray(
            np.broadcast_to(bt[None], (self.num_layers,) + bt.shape))

    def _prefill_tick(self) -> None:
        launches = 0
        for si, seq in enumerate(self.slots):
            if not isinstance(seq, _Seq) or seq.phase != "prefill":
                continue
            launches += 1
            toks = seq.entry.tokens
            n = len(toks)
            take = min(self.chunk, n - seq.pos)
            buf = np.zeros((1, self.chunk), np.int32)
            buf[0, :take] = toks[seq.pos:seq.pos + take]
            cache = {"k": self.kv["k"], "v": self.kv["v"],
                     "bt": self._layered_bt(self._bt_row(seq)[None])}
            t0 = time.perf_counter()
            with self.trace.span("prefill_chunk",
                                 tid=obs.request_tid(seq.rid),
                                 pos=seq.pos, take=take):
                with self._ctx():
                    logits, cache = self._chunk(
                        self.params, jnp.asarray(buf), cache,
                        jnp.asarray([seq.pos], jnp.int32))
                if self.trace.enabled or self.metrics.enabled:
                    # async dispatch: sync so the span/histogram cover
                    # the device step, not just its launch
                    jax.block_until_ready(logits)
            self.metrics.histogram("prefill_chunk_seconds").observe(
                time.perf_counter() - t0)
            self.metrics.counter("prefill_chunks_total").inc()
            self.kv = {"k": cache["k"], "v": cache["v"]}
            seq.pos += take
            if seq.pos < n:
                continue
            # prompt complete: publish full-block prefix hashes and seed
            # decode with the last REAL row of the chunk logits
            if self.prefix_cache:
                hashes = prefix_hashes(toks, self.block_size)
                for i in range(seq.n_shared, n // self.block_size):
                    self.pool.register_prefix(seq.table[i], hashes[i])
            seq.phase = "decode"
            seq.pos = n
            nb = seq.entry.req.n_best
            if nb == 1:
                first = int(jnp.argmax(logits[0, take - 1]))
                self._note_first_token(seq, first)
                if self.handoff is not None:
                    # disaggregated serving (§13): prefill's job ends
                    # here — the callback ships the KV payload + first
                    # token to the decode pool instead of decoding
                    self.trace.event("handoff",
                                     tid=obs.request_tid(seq.rid))
                    self.handoff(self, si, seq, first)
                    self._end_req(seq.rid, "handoff")
                else:
                    self._emit(si, first)
                continue
            # beam fork (§12): rank r continues the r-th best first
            # token; tables are forked by refcount — the first decode
            # write into the shared partial tail block copy-on-writes it
            firsts = np.asarray(api.topn_tokens(logits[0, take - 1], nb))
            self._note_first_token(seq, int(firsts[0]))
            holds = [hi for hi, s in enumerate(self.slots)
                     if isinstance(s, _Hold) and s.rid == seq.rid]
            assert len(holds) == nb - 1, (seq.rid, holds)
            for r, hsi in enumerate(holds, start=1):
                self.slots[hsi] = _Seq(
                    entry=seq.entry, table=self.pool.fork(seq.table),
                    n_shared=seq.n_shared, pos=n, phase="decode",
                    ticket=seq.ticket, rank=r)
                self._emit(hsi, int(firsts[r]))
            self._emit(si, int(firsts[0]))
        if launches:
            self.tick_prefill.append(launches)

    # -- telemetry helpers (DESIGN.md §15) -------------------------------
    def _note_first_token(self, seq: _Seq, tok: int) -> None:
        """TTFT mark at prompt completion. Replayed admissions emitted
        their real first token before the preemption, so only the first
        attempt observes TTFT (the replay's prompt-complete instant is
        a recompute artifact, not a user-visible first token)."""
        if not (self.trace.enabled or self.metrics.enabled):
            return
        if seq.entry.replays or seq.entry.pre_out:
            return
        t0 = self._admit_t.get(seq.rid)
        if t0 is not None:
            self.metrics.histogram("ttft_seconds").observe(
                time.perf_counter() - t0)
        self.trace.event("first_token", tid=obs.request_tid(seq.rid),
                         token=tok)

    def _end_req(self, rid: int, outcome: str) -> None:
        """Close the request's lifecycle root span (no-op when tracing
        is off or the root was already closed)."""
        self.trace.end(self._req_span.pop(rid, 0), outcome=outcome)

    # -- decode growth / COW / preemption --------------------------------
    def _release_seq(self, seq: _Seq) -> None:
        for bid in seq.table:
            self.pool.release(bid)

    def _release_slot(self, si: int) -> None:
        s = self.slots[si]
        if isinstance(s, _Seq):
            self._release_seq(s)
            if self.spec is not None:
                self.spec.draft.release((s.rid, s.rank))
        self.slots[si] = None
        self.tokens[si, 0] = 0

    def _preempt_youngest(self) -> bool:
        """Evict the latest-admitted active request (a beam group as a
        unit); False if there is nothing else to evict (pool genuinely
        exhausted)."""
        cands = [(s.ticket, si) for si, s in enumerate(self.slots)
                 if isinstance(s, _Seq)]
        if not cands:
            return False
        _, vsi = max(cands)
        victim = self.slots[vsi]
        rid = victim.rid
        group = [si for si, s in enumerate(self.slots)
                 if isinstance(s, (_Seq, _Hold)) and s.rid == rid]
        if all(si in group for _, si in cands):
            return False                   # the victim is all that runs
        nb = victim.entry.req.n_best
        for si in group:
            self._release_slot(si)
        replays = victim.entry.replays + 1
        if nb > 1:
            # forks diverge per rank — replay the whole group from
            # scratch (deterministic top-n fork → identical re-run)
            self._group_out[rid] = [None] * nb
            self.queue.appendleft(_Entry(victim.entry.req,
                                         replays=replays))
        else:
            self.queue.appendleft(
                _Entry(victim.entry.req,
                       victim.entry.pre_out + victim.out,
                       replays=replays))
        self.trace.event("preempt", tid=obs.request_tid(rid))
        self._end_req(rid, "preempt")
        self.metrics.counter("requests_preempted_total").inc()
        self._admit_t.pop(rid, None)
        return True

    def _copy_block(self, dst: int, src: int) -> None:
        """Device-side COW copy of one pool block (all layers, K and V)."""
        d = jnp.asarray(dst, jnp.int32)
        s = jnp.asarray(src, jnp.int32)
        with self._ctx():
            self.kv = {"k": self._blk_copy(self.kv["k"], d, s),
                       "v": self._blk_copy(self.kv["v"], d, s)}

    def _ensure_capacity(self, si: int, last_pos: int) -> bool:
        """Make slot ``si`` writable through position ``last_pos``: grow
        the table with fresh blocks and copy-on-write any shared block
        in the write range [seq.pos, last_pos] (beam forks share the
        prompt tail until their first write). Preempts on a dry pool;
        returns False if the slot itself was preempted away. Positions
        past max_len (a speculative chunk's overhang near the end) need
        no blocks — ``write_kv_cache_paged`` routes them to the null
        block."""
        last_blk = min(last_pos // self.block_size, self.nbmax - 1)
        while True:
            seq = self.slots[si]
            if not isinstance(seq, _Seq) or seq.phase != "decode":
                return False
            todo = None
            if len(seq.table) <= last_blk:
                todo = ("grow", None)
            else:
                for i in range(seq.pos // self.block_size, last_blk + 1):
                    if not self.pool.writable(seq.table[i]):
                        todo = ("cow", i)
                        break
            if todo is None:
                return True
            kind, i = todo
            if kind == "grow":
                bid = self.pool.alloc()
                if bid is not None:
                    seq.table.append(bid)
                    continue
            else:
                old = seq.table[i]
                new = self.pool.cow(old)
                if new is not None:
                    # the old block's contents are intact (live holders,
                    # or parked in the prefix cache) — copy then swap
                    self._copy_block(new, old)
                    seq.table[i] = new
                    continue
            if not self._preempt_youngest():
                raise RuntimeError(
                    "KV pool exhausted with a single active "
                    "request/group; need num_blocks >= "
                    f"{self.nbmax + 2}")

    def _grow_or_preempt(self) -> None:
        for si in range(self.n_slots):
            seq = self.slots[si]
            if isinstance(seq, _Seq) and seq.phase == "decode":
                self._ensure_capacity(si, seq.pos)

    # -- decode ----------------------------------------------------------
    def _decode_tick(self) -> None:
        live = [si for si, s in enumerate(self.slots)
                if isinstance(s, _Seq) and s.phase == "decode"]
        if not live:
            return
        self.tick_active.append(len(live))
        self.metrics.counter("decode_ticks_total").inc()
        self.metrics.histogram("tick_active",
                               buckets=_COUNT_BUCKETS).observe(len(live))
        t0 = time.perf_counter()
        with self.trace.span("decode_tick", n_active=len(live)):
            bt = np.zeros((self.n_slots, self.nbmax), np.int32)
            pos = np.zeros(self.n_slots, np.int32)
            for si in live:
                bt[si] = self._bt_row(self.slots[si])
                pos[si] = self.slots[si].pos
            cache = {"k": self.kv["k"], "v": self.kv["v"],
                     "bt": self._layered_bt(bt)}
            with self._ctx():
                logits, cache = self._decode(
                    self.params, jnp.asarray(self.tokens), cache,
                    jnp.asarray(pos, jnp.int32))
            self.kv = {"k": cache["k"], "v": cache["v"]}
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)  # syncs
        self.metrics.histogram("decode_tick_seconds").observe(
            time.perf_counter() - t0)
        self.tick_emitted.append(len(live))
        for si in live:
            self.slots[si].pos += 1
            self._emit(si, int(nxt[si]))

    # -- speculative decode (§12) ----------------------------------------
    def _spec_tick(self) -> None:
        """Draft k, verify k+1 in one paged chunk dispatch, accept the
        longest matching draft prefix + the target's bonus token, roll
        back by table truncation."""
        K = self.spec.k
        for si in range(self.n_slots):
            s = self.slots[si]
            if isinstance(s, _Seq) and s.phase == "decode":
                # the pass writes K/V at pos..pos+K — grow/COW up front
                self._ensure_capacity(si, s.pos + K)
        live = [si for si, s in enumerate(self.slots)
                if isinstance(s, _Seq) and s.phase == "decode"]
        if not live:
            return
        self.tick_active.append(len(live))
        self.metrics.counter("verify_passes_total").inc(len(live))
        self.metrics.histogram("tick_active",
                               buckets=_COUNT_BUCKETS).observe(len(live))
        t0 = time.perf_counter()
        with self.trace.span("verify_pass", n_active=len(live)):
            drafts: Dict[int, List[int]] = {}
            with self.trace.span("draft", n_active=len(live)):
                for si in live:
                    seq = self.slots[si]
                    # the draft sees everything emitted so far: prompt,
                    # replayed pre_out, and out (whose last element is
                    # the pending token)
                    drafts[si] = list(self.spec.draft.draft(
                        (seq.rid, seq.rank), seq.entry.tokens + seq.out,
                        K))
                    assert len(drafts[si]) == K, (si, drafts[si])
            buf = np.zeros((self.n_slots, K + 1), np.int32)
            bt = np.zeros((self.n_slots, self.nbmax), np.int32)
            pos = np.zeros(self.n_slots, np.int32)
            for si in live:
                seq = self.slots[si]
                buf[si, 0] = self.tokens[si, 0]      # pending token
                buf[si, 1:] = drafts[si]
                bt[si] = self._bt_row(seq)
                pos[si] = seq.pos
            cache = {"k": self.kv["k"], "v": self.kv["v"],
                     "bt": self._layered_bt(bt)}
            with self._ctx():
                logits, cache = self._verify(
                    self.params, jnp.asarray(buf), cache,
                    jnp.asarray(pos, jnp.int32))
            self.kv = {"k": cache["k"], "v": cache["v"]}
            tgt = np.asarray(jnp.argmax(logits, -1), np.int32)  # (B, K+1)
        self.metrics.histogram("verify_pass_seconds").observe(
            time.perf_counter() - t0)
        emitted = 0
        for si in live:
            seq = self.slots[si]
            a = accept_length(drafts[si], tgt[si])
            self.spec_passes += 1
            self.spec_drafted += K
            self.spec_accepted += a
            self.metrics.histogram("accepted_draft_length",
                                   buckets=_COUNT_BUCKETS).observe(a)
            if a < K:
                self.trace.event("rollback", tid=obs.request_tid(seq.rid),
                                 accepted=a)
            # positions pos..pos+a now hold correct K/V ([pending,
            # accepted drafts]); the bonus token is emitted un-cached —
            # it is the next pass's pending token
            seq.pos += a + 1
            for tok in drafts[si][:a] + [int(tgt[si, a])]:
                emitted += 1
                self._emit(si, int(tok))
                if self.slots[si] is not seq:    # eos / max_new mid-pass
                    break
            if self.slots[si] is seq:
                self._truncate(seq)
        self.tick_emitted.append(emitted)

    def _truncate(self, seq: _Seq) -> None:
        """Speculative rollback: drop table blocks wholly past the
        accepted prefix. No KV rewrite — stale slots inside the kept
        tail block sit at kpos > qpos until the next pass's chunk write
        overwrites them (the §11 validity invariant)."""
        keep = max(-(-seq.pos // self.block_size), 1)
        while len(seq.table) > keep:
            self.pool.release(seq.table.pop())

    # -- disaggregated prefill→decode handoff (§13) ----------------------
    def gather_blocks(self, table: List[int]):
        """Device-side (L, nb, BS, Hkv, D) copies of ``table``'s K and V
        blocks — the handoff payload. Sharded exactly like the pool, so
        a cross-mesh ``device_put`` moves each data shard straight to
        its counterpart device without ever gathering a full block."""
        ids = jnp.asarray(np.asarray(table, np.int32))
        with self._ctx():
            return (self._blk_gather(self.kv["k"], ids),
                    self._blk_gather(self.kv["v"], ids))

    def can_adopt(self, entry: _Entry) -> bool:
        """Room for one handed-off sequence: a free slot plus its prompt
        blocks and one block of decode headroom."""
        need = -(-len(entry.tokens) // self.block_size)
        return any(s is None for s in self.slots) \
            and self.pool.num_free >= need + 1

    def adopt(self, entry: _Entry, first_tok: int, kv_blocks) -> None:
        """Install a sequence prefilled on ANOTHER scheduler: allocate
        private blocks, scatter the transferred payload into them, then
        emit the prefill side's first token exactly as a local prefill
        completion would — greedy decode from identical KV makes the
        handed-off stream token-identical to unified serving. Adopted
        blocks are private (no prefix-cache registration, first cut); a
        later preemption replays the request locally from its tokens."""
        assert self.can_adopt(entry), "call can_adopt first"
        toks = entry.tokens
        n = len(toks)
        table = []
        for _ in range(-(-n // self.block_size)):
            bid = self.pool.alloc()
            assert bid is not None
            table.append(bid)
        k_blk, v_blk = kv_blocks
        if self.mesh is not None:
            sh = jax.sharding.NamedSharding(self.mesh, self._pool_sh.spec)
            k_blk = jax.device_put(k_blk, sh)     # shard → shard move
            v_blk = jax.device_put(v_blk, sh)
        ids = jnp.asarray(np.asarray(table, np.int32))
        with self._ctx():
            self.kv = {"k": self._adopt_copy(self.kv["k"], ids, k_blk),
                       "v": self._adopt_copy(self.kv["v"], ids, v_blk)}
        si = next(i for i, s in enumerate(self.slots) if s is None)
        self.slots[si] = _Seq(entry=entry, table=table, n_shared=0,
                              pos=n, phase="decode", ticket=self._ticket)
        self._ticket += 1
        if self.trace.enabled or self.metrics.enabled:
            rid = entry.req.rid
            self._req_span[rid] = self.trace.begin(
                "request", tid=obs.request_tid(rid), rid=rid,
                adopted=True, replays=entry.replays)
            self.trace.event("adopt", tid=obs.request_tid(rid))
            self.metrics.counter("adoptions_total").inc()
        self._emit(si, first_tok)

    def _emit(self, si: int, tok: int) -> None:
        seq = self.slots[si]
        seq.out.append(tok)
        if self.metrics.enabled:
            now = time.perf_counter()
            self.metrics.counter("tokens_emitted_total").inc()
            if seq.t_emit:
                self.metrics.histogram("inter_token_seconds").observe(
                    now - seq.t_emit)
            seq.t_emit = now
        req = seq.entry.req
        if seq.emitted >= req.max_new or \
                (req.eos is not None and tok == req.eos):
            out = seq.entry.pre_out + seq.out
            finished = True
            if req.n_best > 1:
                grp = self._group_out[req.rid]
                grp[seq.rank] = out
                finished = all(o is not None for o in grp)
                if finished:
                    self.done[req.rid] = list(grp)
                    del self._group_out[req.rid]
            else:
                self.done[req.rid] = out
            if finished:
                self.trace.event("finish", tid=obs.request_tid(req.rid))
                self._end_req(req.rid, "finish")
                self.metrics.counter("requests_finished_total").inc()
                self._admit_t.pop(req.rid, None)
            self._release_slot(si)
        else:
            self.tokens[si, 0] = tok
