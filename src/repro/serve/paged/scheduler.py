"""Paged-KV continuous-batching scheduler (DESIGN.md §10).

Replaces the dense slot loop of ``serve.batching.ContinuousBatcher``:

* **Admission by free-block budget** — a request is admitted when the
  pool can cover its prompt blocks (minus any prefix-cache hits) plus
  one block of decode headroom; admission is FIFO, no head-of-line skip.
* **Chunked prefill** — prompts stream into the pool ``chunk`` tokens
  per tick, interleaved with decode ticks of the already-running slots,
  through one fixed-shape jitted chunk step (the last chunk is padded;
  the first generated token is read from the last *real* row of the
  full-chunk logits, so there is no power-of-two bucket padding and no
  re-decode-the-last-prompt-token hack).
* **Prefix sharing** — full prompt blocks are content-hashed; a new
  request retains matching cached blocks instead of recomputing them
  (capped at (n-1)//BS blocks so the block holding the last prompt
  token — whose logits seed decode — is always privately recomputed and
  shared blocks are never written).
* **Preemption by eviction** — when the pool runs dry mid-decode the
  youngest running request is evicted (blocks released, request
  re-queued at the front); greedy decoding makes the later re-run
  token-identical, so preemption trades recompute for memory, never
  correctness.

Exactness: every tick runs the same model step functions as the dense
engine over the same masked shapes (virtual length NBMAX·BS == the
dense engine's max_len), so greedy outputs are token-identical to
``Engine.generate`` — asserted across dense/MoE/VLM in
tests/test_paged.py. Caveat: on the Pallas kernel path (TPU /
force_pallas) with ``use_lut_softmax=True`` the paged kernel caps the
softmax group at the block size while the dense kernel uses
``cfg.softmax_group``; LUT grouping is numerics-visible, so kernel-path
LUT serving agrees with the dense engine only to LUT tolerance, not
token-identically (exact-exp mode and the off-TPU ref path are
unaffected — DESIGN.md §10).

The per-tick decode-active counts feed the WS-OCS weight-stream
amortization model (``sim.perf_model.scheduler_amortization_report``):
the RCW-bound weight stream is paid once per tick and divided by the
number of active decode slots — the denominator this subsystem exists
to keep high. Per-tick prefill chunk-launch counts (``tick_prefill``)
ride along in the same report so prefill batching is measured the same
way.

Since PR 6 the chunk step's attention consumes the block table
*directly*: ``models.layers`` routes it to ``ops.paged_flash_prefill``,
whose Pallas kernel gathers K/V pool blocks through a scalar-prefetched
table (DESIGN.md §11) — the scheduler no longer triggers any dense
``gather_paged_kv`` copy of the prefix on the chunk path, so
prefix-cache hits are never re-densified.
"""
from __future__ import annotations

import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from collections import deque

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve.batching import Request
from repro.serve.paged.block_pool import KVBlockPool, prefix_hashes


@dataclasses.dataclass
class _Entry:
    """Queue entry: the request plus tokens already emitted before a
    preemption (greedy decode resumes exactly by prefilling them)."""
    req: Request
    pre_out: List[int] = dataclasses.field(default_factory=list)

    @property
    def tokens(self) -> List[int]:
        return list(self.req.prompt) + self.pre_out


@dataclasses.dataclass
class _Seq:
    entry: _Entry
    table: List[int]                  # physical block ids, logical order
    n_shared: int                     # leading blocks retained from cache
    pos: int                          # next cache write position
    phase: str                        # "prefill" | "decode"
    ticket: int                       # admission order (preemption prio)
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def rid(self) -> int:
        return self.entry.req.rid

    @property
    def emitted(self) -> int:
        return len(self.entry.pre_out) + len(self.out)


class Scheduler:
    """Drives dense/MoE/VLM decode over a paged KV pool. ``num_blocks``
    includes the reserved null block; it must be at least
    max_len//block_size + 2 so a lone request can always run."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 512, block_size: int = 16,
                 num_blocks: Optional[int] = None, chunk: int = 32,
                 prefix_cache: bool = True):
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = slots, max_len
        self.block_size, self.chunk = block_size, chunk
        self.nbmax = max_len // block_size
        if num_blocks is None:                       # dense-equivalent
            num_blocks = max(slots * self.nbmax + 1, self.nbmax + 2)
        assert num_blocks >= self.nbmax + 2, \
            f"pool too small: {num_blocks} < {self.nbmax + 2}"
        self.pool = KVBlockPool(num_blocks, block_size)
        self.prefix_cache = prefix_cache

        cache = api.init_cache(cfg, slots, max_len, num_blocks=num_blocks,
                               block_size=block_size)
        self.kv = {"k": cache["k"], "v": cache["v"]}   # (L, NB, BS, Hkv, D)
        self.num_layers = cache["k"].shape[0]

        self.queue: Deque[_Entry] = deque()
        self.slots: List[Optional[_Seq]] = [None] * slots
        self.done: Dict[int, List[int]] = {}
        self.tokens = np.zeros((slots, 1), np.int32)
        self._ticket = 0
        self.tick_active: List[int] = []         # decode slots per tick
        self.tick_prefill: List[int] = []        # prefill chunk launches/tick

        self._decode = jax.jit(
            lambda p, t, c, i: api.serve_step(p, cfg, t, c, i))
        self._chunk = jax.jit(
            lambda p, t, c, s: api.prefill_chunk_step(
                p, cfg, {"tokens": t}, c, s))

    # -- public API ------------------------------------------------------
    def submit(self, req: Request) -> None:
        n = len(req.prompt)
        assert n >= 1 and n + req.max_new - 1 <= self.max_len, \
            (n, req.max_new, self.max_len)
        self.queue.append(_Entry(req))

    def run(self, max_ticks: int = 100_000) -> Dict[int, List[int]]:
        """Drive until queue and slots drain; returns rid → generated."""
        for _ in range(max_ticks):
            active = any(s is not None for s in self.slots)
            if not active and not self.queue:
                break
            self._admit()
            self._prefill_tick()
            self._grow_or_preempt()
            self._decode_tick()
        return self.done

    # -- memory accounting ----------------------------------------------
    def _block_bytes(self) -> int:
        k = self.kv["k"]          # (L, NB, BS, Hkv, D)
        per_tok = int(np.prod(k.shape[3:])) * k.dtype.itemsize
        return 2 * self.num_layers * self.block_size * per_tok   # K + V

    def kv_bytes_peak(self) -> int:
        """Peak bytes of *referenced* KV blocks across the run."""
        return self.pool.peak_in_use * self._block_bytes()

    def kv_bytes_dense_equiv(self) -> int:
        """What the dense per-slot layout would have allocated."""
        return self.n_slots * self.nbmax * self._block_bytes()

    def stream_amortization_report(self) -> Dict[str, float]:
        from repro.sim.perf_model import scheduler_amortization_report
        return scheduler_amortization_report(self.tick_active,
                                             prefill_counts=self.tick_prefill)

    # -- admission -------------------------------------------------------
    def _admit(self) -> None:
        for si in range(self.n_slots):
            if not self.queue:
                return
            if self.slots[si] is not None:
                continue
            entry = self.queue[0]
            toks = entry.tokens
            n = len(toks)
            shared = self.pool.match_prefix(toks) if self.prefix_cache \
                else []
            # the block holding the last prompt token is always private:
            # its logits row seeds decode and its tail keeps growing
            shared = shared[:(n - 1) // self.block_size]
            need = -(-n // self.block_size) - len(shared)
            # shared blocks sitting in the prefix cache count in num_free
            # (evictable) but retaining them consumes that allocatability
            cached_shared = sum(self.pool.is_cached(b) for b in shared)
            if self.pool.num_free - cached_shared < need + 1:  # +1 decode
                return                            # FIFO: no queue skip
            self.queue.popleft()
            for bid in shared:
                self.pool.retain(bid)
            table = list(shared)
            for _ in range(need):
                bid = self.pool.alloc()
                if bid is None:                   # accounting drift guard
                    for b in table:
                        self.pool.release(b)
                    self.queue.appendleft(entry)
                    return
                table.append(bid)
            self.slots[si] = _Seq(entry=entry, table=table,
                                  n_shared=len(shared),
                                  pos=len(shared) * self.block_size,
                                  phase="prefill", ticket=self._ticket)
            self._ticket += 1

    # -- chunked prefill -------------------------------------------------
    def _bt_row(self, seq: Optional[_Seq]) -> np.ndarray:
        row = np.zeros(self.nbmax, np.int32)
        if seq is not None:
            row[:len(seq.table)] = seq.table
        return row

    def _layered_bt(self, bt: np.ndarray) -> jnp.ndarray:
        """(B, NBMAX) → (L, B, NBMAX): one logical table broadcast over
        the layer axis so the layer scan threads it (DESIGN.md §10)."""
        return jnp.asarray(
            np.broadcast_to(bt[None], (self.num_layers,) + bt.shape))

    def _prefill_tick(self) -> None:
        launches = 0
        for si, seq in enumerate(self.slots):
            if seq is None or seq.phase != "prefill":
                continue
            launches += 1
            toks = seq.entry.tokens
            n = len(toks)
            take = min(self.chunk, n - seq.pos)
            buf = np.zeros((1, self.chunk), np.int32)
            buf[0, :take] = toks[seq.pos:seq.pos + take]
            cache = {"k": self.kv["k"], "v": self.kv["v"],
                     "bt": self._layered_bt(self._bt_row(seq)[None])}
            logits, cache = self._chunk(
                self.params, jnp.asarray(buf), cache,
                jnp.asarray([seq.pos], jnp.int32))
            self.kv = {"k": cache["k"], "v": cache["v"]}
            seq.pos += take
            if seq.pos < n:
                continue
            # prompt complete: publish full-block prefix hashes and seed
            # decode with the last REAL row of the chunk logits
            if self.prefix_cache:
                hashes = prefix_hashes(toks, self.block_size)
                for i in range(seq.n_shared, n // self.block_size):
                    self.pool.register_prefix(seq.table[i], hashes[i])
            seq.phase = "decode"
            seq.pos = n
            first = int(jnp.argmax(logits[0, take - 1]))
            self._emit(si, first)
        if launches:
            self.tick_prefill.append(launches)

    # -- decode growth / preemption --------------------------------------
    def _release_seq(self, seq: _Seq) -> None:
        for bid in seq.table:
            self.pool.release(bid)

    def _preempt_youngest(self) -> bool:
        """Evict the latest-admitted active request; False if there is
        no other request to evict (pool genuinely exhausted)."""
        cands = [(s.ticket, si) for si, s in enumerate(self.slots)
                 if s is not None]
        if len(cands) <= 1:
            return False
        _, si = max(cands)
        seq = self.slots[si]
        self._release_seq(seq)
        self.queue.appendleft(
            _Entry(seq.entry.req, seq.entry.pre_out + seq.out))
        self.slots[si] = None
        self.tokens[si, 0] = 0
        return True

    def _grow_or_preempt(self) -> None:
        for si in range(self.n_slots):
            seq = self.slots[si]
            if seq is None or seq.phase != "decode":
                continue
            while seq.pos // self.block_size >= len(seq.table):
                bid = self.pool.alloc()
                if bid is not None:
                    seq.table.append(bid)
                    continue
                if not self._preempt_youngest():
                    raise RuntimeError(
                        "KV pool exhausted with a single active request; "
                        f"need num_blocks >= {self.nbmax + 2}")
                seq = self.slots[si]      # the victim may be this slot
                if seq is None or seq.phase != "decode":
                    break

    # -- decode ----------------------------------------------------------
    def _decode_tick(self) -> None:
        live = [si for si, s in enumerate(self.slots)
                if s is not None and s.phase == "decode"]
        if not live:
            return
        self.tick_active.append(len(live))
        bt = np.zeros((self.n_slots, self.nbmax), np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        for si in live:
            bt[si] = self._bt_row(self.slots[si])
            pos[si] = self.slots[si].pos
        cache = {"k": self.kv["k"], "v": self.kv["v"],
                 "bt": self._layered_bt(bt)}
        logits, cache = self._decode(
            self.params, jnp.asarray(self.tokens), cache,
            jnp.asarray(pos, jnp.int32))
        self.kv = {"k": cache["k"], "v": cache["v"]}
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for si in live:
            self.slots[si].pos += 1
            self._emit(si, int(nxt[si]))

    def _emit(self, si: int, tok: int) -> None:
        seq = self.slots[si]
        seq.out.append(tok)
        req = seq.entry.req
        if seq.emitted >= req.max_new or \
                (req.eos is not None and tok == req.eos):
            self.done[req.rid] = seq.entry.pre_out + seq.out
            self._release_seq(seq)
            self.slots[si] = None
            self.tokens[si, 0] = 0
        else:
            self.tokens[si, 0] = tok
