"""Paged-KV serving subsystem (DESIGN.md §10): a ref-counted block pool
with hash-based prefix sharing, and a chunked-prefill scheduler that
replaces the dense per-slot cache of ``serve.batching`` with block-table
indirection through the paged fused decode kernel."""
from repro.serve.paged.block_pool import KVBlockPool, prefix_hashes
from repro.serve.paged.scheduler import Scheduler

__all__ = ["KVBlockPool", "Scheduler", "prefix_hashes"]
