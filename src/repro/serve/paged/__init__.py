"""Paged-KV serving subsystem (DESIGN.md §10–§12): a ref-counted block
pool with hash-based prefix sharing and copy-on-write forking, and a
chunked-prefill scheduler that replaces the dense per-slot cache of
``serve.batching`` with block-table indirection through the paged fused
decode kernel — plus n-best beam forking and k-draft speculative decode
over the same block tables."""
from repro.serve.paged.block_pool import KVBlockPool, prefix_hashes
from repro.serve.paged.disagg import DisaggScheduler
from repro.serve.paged.scheduler import Scheduler

__all__ = ["DisaggScheduler", "KVBlockPool", "Scheduler", "prefix_hashes"]
