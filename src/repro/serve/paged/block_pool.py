"""Host-side KV block-pool bookkeeping (DESIGN.md §10).

The pool owns the physical block ids of the jit-side K/V pools
(``models.layers.make_paged_attn_cache``). Block 0 is the reserved
*null* block: unallocated block-table entries point at it, so inactive
decode slots and chunk padding write there harmlessly and masked reads
never observe it.

Prefix sharing is hash-based, vLLM style: a *full* block holding prompt
tokens is registered under the rolling hash of the entire token prefix
up to and including that block, so any request whose prompt starts with
the same tokens maps the block into its table (ref-counted — stored
once, shared by all). Blocks whose refcount drops to zero but that
carry a prefix hash stay *cached*: they keep their contents and remain
reusable until the allocator evicts them LRU-first when the free list
runs dry. Unhashed blocks (decode-generated tokens, partial prompt
tails) return straight to the free list.

Copy-on-write forking (DESIGN.md §12): ``fork`` clones a sequence's
block table by bumping refcounts — no KV bytes move. A holder may only
write a block it owns exclusively (``writable``: refcount 1 and not
published under a prefix hash); before writing a shared block the
holder calls ``cow`` to trade its reference for a fresh private block
and copies the contents (the scheduler owns the device-side copy — the
pool only does the bookkeeping). Beam / parallel sampling and
speculative rollback are built on these three primitives.

Stats counters (cheap ints): ``prefix_hits`` / ``prefix_misses`` count
``match_prefix`` probes per full block, ``evictions`` counts cached
blocks reclaimed LRU-first by ``alloc``, ``cow_copies`` counts ``cow``
calls, and ``peak_in_use`` is the occupancy high-water mark. A
long-running holder (one scheduler serving several benchmark arms)
calls ``reset_stats`` between arms so per-arm numbers are not
contaminated by earlier runs; the reset touches only the counters —
allocation state, refcounts and the prefix cache are untouched.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple


def prefix_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Rolling prefix hash per FULL block: entry i covers
    tokens[0:(i+1)·block_size]. Only full blocks are hashable — a
    partial tail block's contents still change as the prompt grows."""
    out, h = [], None
    for i in range(len(tokens) // block_size):
        blk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h, blk))
        out.append(h)
    return out


class KVBlockPool:
    """Allocator for ``num_blocks`` physical blocks of ``block_size``
    tokens. Thread-unsafe by design (the scheduler is a single loop)."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least the null block + one"
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, num_blocks))   # 0 = null block
        self._ref: Dict[int, int] = {}
        self._hash_to_block: Dict[int, int] = {}
        self._block_hash: Dict[int, int] = {}
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        self.peak_in_use = 0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.evictions = 0
        self.cow_copies = 0

    # -- accounting ------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Blocks referenced by at least one live request."""
        return len(self._ref)

    @property
    def num_free(self) -> int:
        """Blocks allocatable right now (free list + evictable cached)."""
        return len(self._free) + len(self._cached)

    def _note_usage(self) -> None:
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)

    # -- allocation ------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Allocate a fresh (or LRU-evicted cached) block with refcount 1.
        Returns None when the pool is exhausted (caller preempts)."""
        if self._free:
            bid = self._free.popleft()
        elif self._cached:
            bid, _ = self._cached.popitem(last=False)     # LRU eviction
            h = self._block_hash.pop(bid)
            del self._hash_to_block[h]
            self.evictions += 1
        else:
            return None
        self._ref[bid] = 1
        self._note_usage()
        return bid

    def retain(self, bid: int) -> None:
        """Add a reference (prefix reuse or an extra holder)."""
        if bid in self._ref:
            self._ref[bid] += 1
            return
        # reviving a cached (ref==0) block
        del self._cached[bid]
        self._ref[bid] = 1
        self._note_usage()

    def release(self, bid: int) -> None:
        """Drop one reference; at zero the block becomes evictable-cached
        (if prefix-hashed) or immediately free."""
        n = self._ref[bid] - 1
        if n > 0:
            self._ref[bid] = n
            return
        del self._ref[bid]
        if bid in self._block_hash:
            self._cached[bid] = None
            self._cached.move_to_end(bid)
        else:
            self._free.append(bid)

    # -- copy-on-write forking (DESIGN.md §12) ---------------------------
    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def writable(self, bid: int) -> bool:
        """True when the caller may scatter into the block in place:
        exactly one live reference and no published prefix hash (writing
        a hashed block would poison every future ``match_prefix`` hit,
        even at refcount 1 — the hash describes the *current* bytes)."""
        return self._ref.get(bid, 0) == 1 and bid not in self._block_hash

    def fork(self, table: Sequence[int]) -> List[int]:
        """Clone a block table by reference: every block gains a holder,
        zero KV bytes move. The clone is read-shared until a holder's
        first write triggers ``cow`` on the touched block only."""
        for bid in table:
            self.retain(bid)
        return list(table)

    def cow(self, bid: int) -> Optional[int]:
        """Copy-on-write: trade one reference of a shared ``bid`` for a
        fresh private block. Returns the new block id (refcount 1) —
        the CALLER must copy the pool contents ``bid → new`` before its
        write lands — or None when the pool is dry (caller preempts; the
        original reference is untouched on failure)."""
        new = self.alloc()
        if new is None:
            return None
        self.release(bid)
        self.cow_copies += 1
        return new

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of ``match_prefix`` block probes that hit the cache
        (0.0 before any probe). Derived from the resettable counters, so
        ``reset_stats`` restarts it at 0."""
        probes = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / probes if probes else 0.0

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 prefix blocks parked in the LRU cache."""
        return len(self._cached)

    @property
    def fragmentation(self) -> float:
        """Fraction of allocatable capacity that is *fragmented into the
        prefix cache*: blocks counted in ``num_free`` but reclaimable
        only by evicting a cached prefix (losing its future hits). 0.0 =
        every free block is immediately usable; →1.0 = admission must
        cannibalize the prefix cache. Instantaneous live state — NOT
        reset by ``reset_stats``."""
        return len(self._cached) / self.num_free if self.num_free else 0.0

    def largest_admissible_tokens(self) -> int:
        """Longest prompt a fresh single-stream request could admit
        right now: its ceil(n/BS) prompt blocks plus one decode-headroom
        block must fit in ``num_free`` (blocks are interchangeable, so
        free capacity is the only constraint — the fragmentation cost is
        the evictions ``alloc`` would charge, see ``fragmentation``)."""
        return max(self.num_free - 1, 0) * self.block_size

    @property
    def stats(self) -> Dict[str, float]:
        return {"prefix_hits": self.prefix_hits,
                "prefix_misses": self.prefix_misses,
                "prefix_hit_rate": self.prefix_hit_rate,
                "evictions": self.evictions,
                "cow_copies": self.cow_copies,
                "peak_in_use": self.peak_in_use,
                "blocks_in_use": self.blocks_in_use,
                "num_free": self.num_free,
                "cached_blocks": self.cached_blocks,
                "fragmentation": self.fragmentation,
                "largest_admissible_tokens":
                    self.largest_admissible_tokens()}

    def reset_stats(self) -> None:
        """Zero the counters and re-seat the high-water mark at the
        CURRENT occupancy (not zero — blocks still referenced by live
        requests are real usage the next arm inherits). Allocation and
        prefix-cache state are untouched, so the live-state derived
        stats (``fragmentation``, ``num_free``, ``cached_blocks``,
        ``largest_admissible_tokens``) keep their values while the
        counter-derived ``prefix_hit_rate`` restarts at 0."""
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.evictions = 0
        self.cow_copies = 0
        self.peak_in_use = self.blocks_in_use

    # -- prefix cache ----------------------------------------------------
    def is_cached(self, bid: int) -> bool:
        """True for a refcount-0 hashed block (allocatable via eviction —
        counted in num_free — but consumed from it when retained)."""
        return bid in self._cached

    def lookup_prefix(self, h: int) -> Optional[int]:
        return self._hash_to_block.get(h)

    def register_prefix(self, bid: int, h: int) -> None:
        """Publish a live block under its prefix hash. First writer wins:
        if the hash is already mapped (a concurrent request computed the
        same prefix), the existing mapping is kept and this block simply
        stays unhashed (it frees normally)."""
        if h in self._hash_to_block or bid in self._block_hash:
            return
        self._hash_to_block[h] = bid
        self._block_hash[bid] = h

    def match_prefix(self, tokens: Sequence[int]) -> List[int]:
        """Longest chain of cached blocks covering the prompt's full
        blocks, in logical order (stops at the first miss). Counts one
        ``prefix_hits`` per matched block and one ``prefix_misses`` for
        the probe that broke the chain (full blocks past it are never
        probed — they cannot match without their predecessor)."""
        out = []
        for h in prefix_hashes(tokens, self.block_size):
            bid = self.lookup_prefix(h)
            if bid is None:
                self.prefix_misses += 1
                break
            self.prefix_hits += 1
            out.append(bid)
        return out
