"""Disaggregated prefill/decode serving (DESIGN.md §13, first cut).

Production serving separates the two phases of a request's life onto
different device pools: prefill is compute-bound (long chunked matmuls,
one request at a time saturates), decode is weight-stream-bound (wants
the biggest possible concurrent batch amortizing each RCW weight pass).
Interleaving them on one pool makes each phase worse at the other's
job — a prefill chunk stalls every decode slot for its duration.

``DisaggScheduler`` composes two ordinary ``Scheduler`` instances over
the two pools of a ``launch.mesh.make_serving_mesh(prefill_data=...)``
split:

* the **prefill** scheduler runs with a ``handoff`` callback — when a
  prompt finishes prefilling, instead of decoding locally it gathers the
  request's KV blocks (``gather_blocks``), frees its slot, and queues a
  ``_Handoff``;
* the driver drains the queue into the **decode** scheduler
  (``adopt``): a cross-mesh ``jax.device_put`` moves each KV block's
  data shard straight to its counterpart decode device (blocks never
  cross the "data" axis, never gather), fresh blocks are allocated in
  the decode pool, and the stream continues greedy decode from the
  handed-off first token.

Token identity: the decode side starts from bit-identical KV (the
payload is a device-side copy, the transfer is lossless) and the same
pending token, and greedy decode is scheduling-order independent — so
outputs match unified single-pool serving exactly, which is asserted in
tests/test_multidevice.py. Backpressure is by refusal: a handoff whose
decode pool lacks a slot or blocks waits in the pending queue (prefill
keeps working; its own slot was already freed).

This is the *protocol* cut — both pools live in one host process and
the payload moves through ``device_put`` rather than an interconnect
fabric; ``sim.perf_model.disaggregated_serving_report`` projects what
the overlap buys on real RCW-CIM hardware where the two pools genuinely
run concurrently.
"""
from __future__ import annotations

import dataclasses
from typing import Deque, Dict, List, Optional

from collections import deque

from repro.configs.base import ModelConfig
from repro.serve.batching import Request
from repro.serve.paged.scheduler import Scheduler
from repro.serve.spec_decode import SpecConfig


@dataclasses.dataclass
class _Handoff:
    """A prefilled sequence in flight between the pools: the request
    entry (prompt + any pre-preemption output), the first generated
    token, and the gathered (L, nb, BS, Hkv, D) K/V payload."""
    entry: object
    first_tok: int
    kv_blocks: tuple

    @property
    def nbytes(self) -> int:
        k, v = self.kv_blocks
        return k.nbytes + v.nbytes


class DisaggScheduler:
    """Two-pool serving: ``prefill`` chunks prompts and hands finished
    sequences to ``decode``, which owns all token generation (including
    speculative decode — drafts never run on the prefill pool).

    ``prefill_kw`` / ``decode_kw`` override per-pool Scheduler knobs
    (slots, num_blocks, chunk, ...); ``spec`` applies to the decode pool
    only. Meshes may be None (single-device protocol tests)."""

    def __init__(self, cfg: ModelConfig, params, *,
                 prefill_mesh=None, decode_mesh=None,
                 slots: int = 4, max_len: int = 512, block_size: int = 16,
                 chunk: int = 32, spec: Optional[SpecConfig] = None,
                 prefill_kw: Optional[Dict] = None,
                 decode_kw: Optional[Dict] = None,
                 trace=None, metrics=None):
        # one trace/metrics pair is shared by BOTH pools (None → the
        # env-gated defaults): a request's lifecycle spans one lane
        # across the prefill root (ends "handoff") and the decode root
        # (begins "adopt"), and the token counters stay globally exact
        base = dict(slots=slots, max_len=max_len, block_size=block_size,
                    chunk=chunk, trace=trace, metrics=metrics)
        self.prefill = Scheduler(
            cfg, params, mesh=prefill_mesh, handoff=self._on_handoff,
            # prefill never decodes: headroom-block demands stay, but
            # prefix sharing still pays off across prompts
            **{**base, **(prefill_kw or {})})
        self.decode = Scheduler(
            cfg, params, mesh=decode_mesh, spec=spec,
            **{**base, **(decode_kw or {})})
        self.pending: Deque[_Handoff] = deque()
        self.handoffs = 0
        self.handoff_bytes = 0

    # -- prefill-side callback -------------------------------------------
    def _on_handoff(self, sched: Scheduler, si: int, seq, first: int):
        payload = sched.gather_blocks(seq.table)
        h = _Handoff(entry=seq.entry, first_tok=first, kv_blocks=payload)
        sched._release_slot(si)
        self.pending.append(h)
        self.handoffs += 1
        self.handoff_bytes += h.nbytes
        sched.metrics.counter("handoffs_total").inc()
        sched.metrics.counter("handoff_bytes_total").inc(h.nbytes)

    # -- driver -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.prefill.submit(req)

    def _drain(self) -> None:
        while self.pending and self.decode.can_adopt(self.pending[0].entry):
            h = self.pending.popleft()
            self.decode.adopt(h.entry, h.first_tok, h.kv_blocks)

    def run(self, max_ticks: int = 100_000) -> Dict[int, List[int]]:
        """Drive both pools until everything drains. One tick = one
        prefill chunk round + one decode round — on real hardware these
        overlap; here they serialize, so wall-clock is NOT the metric
        (the perf_model projects the overlap; tests assert tokens)."""
        from repro.serve.paged.scheduler import _Seq
        for _ in range(max_ticks):
            p, d = self.prefill, self.decode
            busy = self.pending or p.queue or d.queue \
                or any(isinstance(s, _Seq) for s in p.slots) \
                or any(isinstance(s, _Seq) for s in d.slots)
            if not busy:
                break
            p._admit()
            p._prefill_tick()
            self._drain()
            # a preempted adoptee re-enters through the decode pool's own
            # queue (local chunked re-prefill — no second handoff)
            d._admit()
            d._prefill_tick()
            if d.spec is not None:
                d._spec_tick()
            else:
                d._grow_or_preempt()
                d._decode_tick()
        assert not self.pending and not self.prefill.queue, "stalled"
        # the pools were driven by hand (their run() never executed), so
        # fold pool stats here — labeled per pool, since both share one
        # registry
        self.prefill.fold_stats(labels={"pool": "prefill"})
        self.decode.fold_stats(labels={"pool": "decode"})
        return self.decode.done

    # -- reporting ---------------------------------------------------------
    def report(self) -> Dict[str, float]:
        return {
            "handoffs": self.handoffs,
            "handoff_bytes": self.handoff_bytes,
            "prefill_peak_blocks": self.prefill.pool.peak_in_use,
            "decode_peak_blocks": self.decode.pool.peak_in_use,
            "decode_per_device_peak_blocks":
                self.decode.per_device_peak_blocks(),
        }
