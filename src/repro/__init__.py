"""repro: RCW-CIM (read-compute/write DCIM LLM accelerator) reproduced as
a multi-pod JAX/Pallas training + serving framework. See DESIGN.md."""
__version__ = "0.1.0"
