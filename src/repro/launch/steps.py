"""Canonical jit-able step functions (train / prefill / decode) and their
sharding plumbing — the single place the trainer, server, and dry-run get
their compiled steps from.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.parallel import sharding as sh
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def default_opt_config(cfg: ModelConfig) -> OptConfig:
    # 480B-class: bf16 optimizer state so params+m+v fit a 256-chip pod
    # (DESIGN.md §5); everything else keeps fp32 state.
    big = cfg.name.startswith("arctic")
    return OptConfig(state_dtype=jnp.bfloat16 if big else jnp.float32)


def make_train_step(cfg: ModelConfig, oc: Optional[OptConfig] = None,
                    grad_shardings=None):
    """grad_shardings (optional): pin gradients to the param sharding
    immediately after backprop — turns the data-axis gradient all-reduce
    into a reduce-scatter (half the ring wire bytes; §Perf iteration)."""
    oc = oc or default_opt_config(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(params, cfg, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, oc)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    # no grads at inference: rematerialization only duplicates reads
    icfg = cfg.replace(remat=False)

    def prefill_step(params, batch, cache):
        return api.prefill_step(params, icfg, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    icfg = cfg.replace(remat=False)

    def decode_step(params, token, cache, pos_idx):
        return api.serve_step(params, icfg, token, cache, pos_idx)

    return decode_step


# ---------------------------------------------------------------------------
# Sharding helpers: abstract trees + NamedShardings per step kind
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ModelConfig, oc: Optional[OptConfig] = None):
    oc = oc or default_opt_config(cfg)
    p = abstract_params(cfg)
    return jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p), oc))


def param_shardings(cfg: ModelConfig, mesh, rules):
    return sh.tree_shardings(mesh, api.axes(cfg), abstract_params(cfg), rules)


def opt_shardings(cfg: ModelConfig, mesh, rules,
                  oc: Optional[OptConfig] = None):
    ax = api.axes(cfg)
    p = abstract_params(cfg)
    m = sh.tree_shardings(mesh, ax, p, rules)
    return {"m": m, "v": m, "step": sh.replicated(mesh)}


def cache_sharding(cfg: ModelConfig, mesh, rules, cache_struct):
    return sh.tree_shardings(mesh, api.cache_axes(cfg), cache_struct, rules)
