"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch × shape), single-pod mesh, TPU v5e terms:
    compute    = FLOPs_per_device  / 197 TFLOP/s
    memory     = bytes_per_device  / 819 GB/s
    collective = wire_bytes_per_device / 50 GB/s (ring-model per-device
                 wire bytes; see dryrun.parse_collectives)

plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N_active for MoE,
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPS (catches remat and
dispatch overhead). FLOPs/bytes come from the layer-extrapolated analysis
(scan bodies are counted once by XLA cost analysis — see dryrun.py).

    PYTHONPATH=src python -m repro.launch.analysis [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.models import api
from repro.models.layers import is_axes_leaf
from repro.sim.chip import TPU_V5E

PEAK = TPU_V5E.peak_bf16_flops
HBM = TPU_V5E.hbm_bytes_per_s
ICI = TPU_V5E.ici_bytes_per_s_per_link


def model_params(cfg) -> Dict[str, float]:
    """(total, active) parameter counts, embeddings excluded (standard
    6ND convention). Active discounts expert params by topk/E."""
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
    axes = api.axes(cfg)
    flat_s = jax.tree.leaves(shapes)
    flat_a = jax.tree.leaves(axes, is_leaf=is_axes_leaf)
    total = active = 0.0
    for s, a in zip(flat_s, flat_a):
        n = float(np.prod(s.shape))
        if "vocab" in a:          # embedding / lm head
            continue
        total += n
        if "experts" in a and cfg.num_experts:
            active += n * cfg.num_experts_per_tok / cfg.num_experts
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(cfg, shape, n_dev: int) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference), global."""
    p = model_params(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * p * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * p * tokens
    return 2.0 * p * shape.global_batch  # decode: one token per sequence


def roofline_terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok" or "analysis" not in rec:
        return None
    a = rec["analysis"]
    compute = a["flops_per_device"] / PEAK
    memory = a["bytes_per_device"] / HBM
    coll = a["wire_bytes_per_device"] / ICI
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    cfg = get_config(rec["arch"])
    mf = model_flops(cfg, SHAPES[rec["shape"]], rec["n_devices"])
    hlo_global = a["flops_per_device"] * rec["n_devices"]
    useful = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model FLOPs per second at the bound, vs peak
    step_time = bound
    mfu = mf / (step_time * rec["n_devices"] * PEAK) if step_time else 0.0
    return {**terms, "dominant": dom.replace("_s", ""),
            "step_time_s": step_time, "model_flops": mf,
            "useful_ratio": useful, "roofline_fraction": mfu}


def load_records(dirpath: str, mesh: str = "single"):
    recs = {}
    for p in sorted(Path(dirpath).glob(f"*_{mesh}.json")):
        rec = json.loads(p.read_text())
        recs[(rec["arch"], rec["shape"])] = rec
    return recs


def table(dirpath: str = "experiments/dryrun") -> str:
    recs = load_records(dirpath)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MFU | useful | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), rec in sorted(recs.items()):
        if rec.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | skipped "
                         f"(quadratic @500k) | — | — | — |")
            continue
        t = roofline_terms(rec)
        if t is None:
            lines.append(f"| {arch} | {shape} | FAILED | | | | | | |")
            continue
        temp = rec["memory_analysis"].get("temp_bytes") or 0
        lines.append(
            f"| {arch} | {shape} | {t['compute_s']:.3f} | {t['memory_s']:.3f}"
            f" | {t['collective_s']:.3f} | **{t['dominant']}** |"
            f" {t['roofline_fraction']*100:.1f}% | {t['useful_ratio']:.2f} |"
            f" {temp/1e9:.1f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--compare", default=None,
                    help="second records dir: print before/after table")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.compare:
        print(compare(args.dir, args.compare))
        return
    if args.json:
        recs = load_records(args.dir)
        out = {f"{a}/{s}": roofline_terms(r) for (a, s), r in recs.items()
               if r.get("status") == "ok"}
        print(json.dumps(out, indent=1))
    else:
        print(table(args.dir))



def compare(dir_base: str, dir_opt: str) -> str:
    """Before/after table (baseline vs optimized sweeps) — §Perf."""
    base = load_records(dir_base)
    opt = load_records(dir_opt)
    lines = [
        "| arch | shape | base dominant | base step s | opt dominant "
        "| opt step s | speedup | base MFU | opt MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        rb, ro = base.get(key), opt.get(key)
        if not rb or rb.get("status") != "ok" or not ro \
                or ro.get("status") != "ok":
            continue
        tb, to = roofline_terms(rb), roofline_terms(ro)
        if not tb or not to:
            continue
        arch, shape = key
        lines.append(
            f"| {arch} | {shape} | {tb['dominant']} | {tb['step_time_s']:.3f}"
            f" | {to['dominant']} | {to['step_time_s']:.3f}"
            f" | **{tb['step_time_s']/to['step_time_s']:.2f}×**"
            f" | {tb['roofline_fraction']*100:.1f}%"
            f" | {to['roofline_fraction']*100:.1f}% |")
    return "\n".join(lines)

if __name__ == "__main__":
    main()
