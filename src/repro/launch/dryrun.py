import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production mesh with 512 placeholder host devices, and extract the
roofline inputs (FLOPs, bytes, per-device memory, collective traffic)
from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen2-72b --shape train_4k --mesh single \
        [--out experiments/dryrun]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — which is why this module sets it at line 1
and why nothing else in the package sets it globally.
"""
import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_config, long_500k_supported
from repro.configs.specs import input_specs
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.parallel import sharding as sh

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo: str, n_devices: int):
    """Per-device wire-byte estimate per collective op (ring model):
    all-reduce 2B(G−1)/G; all-gather/all-to-all B(G−1)/G (B = result
    bytes); reduce-scatter B(G−1) (operand = B·G); permute B."""
    per_op = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
              for k in _COLL_OPS}
    for line in hlo.splitlines():
        s = line.strip()
        m = re.search(r"= .*? (all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in s:
            continue  # count the -start, not the -done
        result = s.split("=", 1)[1].split(m.group(1))[0]
        B = _shape_bytes(result)
        G = _group_size(s, n_devices)
        if op == "all-reduce":
            wire = 2 * B * (G - 1) / max(G, 1)
        elif op in ("all-gather", "all-to-all"):
            wire = B * (G - 1) / max(G, 1)
        elif op == "reduce-scatter":
            wire = B * (G - 1)
        else:
            wire = float(B)
        d = per_op[op]
        d["count"] += 1
        d["result_bytes"] += B
        d["wire_bytes"] += wire
    return per_op


# ---------------------------------------------------------------------------
# Dry-run of one cell
# ---------------------------------------------------------------------------

def _reduced_depth_cfg(cfg, n: int):
    """Full-width config with n (unrolled) layers — the extrapolation
    probe for per-layer costs. XLA cost_analysis counts a scan body ONCE
    (trip count ignored), so per-layer FLOPs/bytes/collectives are
    derived from two unrolled reduced-depth compiles:
        per_layer = (cost(k2) − cost(k1)) / (k2 − k1)
        total     = cost(k1) + per_layer × (L − k1)
    — still entirely HLO-derived (see EXPERIMENTS.md §Dry-run notes)."""
    kw = dict(num_layers=n, scan_layers=False)
    if cfg.family == "audio":
        kw["encoder_layers"] = n
    return cfg.replace(**kw)


def _probe_depths(cfg):
    if cfg.family == "hybrid":
        p = len(cfg.block_pattern or ("R", "R", "A"))
        return p, 2 * p
    return 2, 4


def _lower_compile(cfg, shape, mesh, donate=True):
    kind, kwargs = input_specs(cfg, shape)
    if kind == "train":
        rules = sh.train_rules()
    elif kind == "decode":
        rules = sh.decode_rules()
    else:
        rules = sh.SERVE_RULES
    with compat.set_mesh(mesh):
        p_sh = st.param_shardings(cfg, mesh, rules)
        if kind == "train":
            from repro.parallel.flags import opt as _opt
            fn = st.make_train_step(
                cfg, grad_shardings=p_sh if _opt("GRADRS", default=False) else None)
            o_sh = st.opt_shardings(cfg, mesh, rules)
            b_sh = sh.batch_specs(kwargs["batch"], mesh, rules)
            jf = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1) if donate else ())
            lowered = jf.lower(st.abstract_params(cfg),
                               st.abstract_opt_state(cfg), kwargs["batch"])
        elif kind == "prefill":
            fn = st.make_prefill_step(cfg)
            b_sh = sh.batch_specs(kwargs["batch"], mesh, rules)
            c_sh = st.cache_sharding(cfg, mesh, rules, kwargs["cache"])
            jf = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                         donate_argnums=(2,) if donate else ())
            lowered = jf.lower(st.abstract_params(cfg), kwargs["batch"],
                               kwargs["cache"])
        else:
            fn = st.make_decode_step(cfg)
            t_sh = sh.batch_specs(kwargs["token"], mesh, rules)
            c_sh = st.cache_sharding(cfg, mesh, rules, kwargs["cache"])
            jf = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh,
                                           sh.replicated(mesh)),
                         donate_argnums=(2,) if donate else ())
            lowered = jf.lower(st.abstract_params(cfg), kwargs["token"],
                               kwargs["cache"],
                               jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    return kind, lowered, compiled


def _cell_costs(cfg, shape, mesh, n_dev):
    """flops/bytes/wire + collectives for one compile."""
    _, lowered, compiled = _lower_compile(cfg, shape, mesh)
    cost = compat.cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo, n_dev)
    return {
        "flops": cost.get("flops", 0.0) or 0.0,
        "bytes": cost.get("bytes accessed", 0.0) or 0.0,
        "wire": sum(d["wire_bytes"] for d in coll.values()),
        "collectives": coll,
    }


def layer_extrapolated_costs(cfg, shape, mesh, n_dev):
    """Per-layer extrapolation from two reduced-depth unrolled compiles.
    Chunked sequence scans (SSM / RG-LRU) are forced to single-chunk so
    their full per-layer work is visible to cost analysis."""
    from repro.models import scan_utils
    k1, k2 = _probe_depths(cfg)
    scan_utils.FULL_CHUNK_ANALYSIS = True
    try:
        c1 = _cell_costs(_reduced_depth_cfg(cfg, k1), shape, mesh, n_dev)
        c2 = _cell_costs(_reduced_depth_cfg(cfg, k2), shape, mesh, n_dev)
    finally:
        scan_utils.FULL_CHUNK_ANALYSIS = False
    L = cfg.num_layers

    def extrap(a, b):
        per = (b - a) / (k2 - k1)
        return a + per * (L - k1), per

    flops, flops_l = extrap(c1["flops"], c2["flops"])
    byts, bytes_l = extrap(c1["bytes"], c2["bytes"])
    wire, wire_l = extrap(c1["wire"], c2["wire"])
    coll = {}
    for op in _COLL_OPS:
        a, b = c1["collectives"][op], c2["collectives"][op]
        coll[op] = {k: extrap(a[k], b[k])[0] for k in
                    ("count", "result_bytes", "wire_bytes")}
    return {
        "probe_depths": [k1, k2],
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "wire_bytes_per_device": wire,
        "per_layer": {"flops": flops_l, "bytes": bytes_l, "wire": wire_l},
        "collectives": coll,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             smoke: bool = False, donate: bool = True,
             analysis: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not long_500k_supported(cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch: 500k dense decode is "
                          "architecturally quadratic (DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    kind, lowered, compiled = _lower_compile(cfg, shape, mesh, donate=donate)
    t_compile = time.time() - t0

    cost = compat.cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}

    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo, n_dev)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "step_kind": kind,
        "status": "ok",
        "n_devices": n_dev,
        "scan_flops_per_device": cost.get("flops"),
        "scan_bytes_per_device": cost.get("bytes accessed"),
        "memory_analysis": mem_d,
        "scan_collectives": coll,
        "compile_s": round(t_compile, 2),
        "hlo_bytes": len(hlo),
    }
    del lowered, compiled, hlo

    if analysis and rec["status"] == "ok":
        t0 = time.time()
        rec["analysis"] = layer_extrapolated_costs(cfg, shape, mesh, n_dev)
        rec["analysis_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    rec = run_cell(args.arch, args.shape, args.mesh == "multi",
                   smoke=args.smoke, analysis=not args.no_analysis)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    name = f"{args.arch}_{args.shape}_{args.mesh}.json"
    (out / name).write_text(json.dumps(rec, indent=1))
    summary = {k: rec.get(k) for k in
               ("arch", "shape", "mesh", "status", "compile_s",
                "analysis_s")}
    if "analysis" in rec:
        summary.update({k: rec["analysis"][k] for k in
                        ("flops_per_device", "bytes_per_device",
                         "wire_bytes_per_device")})
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
