"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
        --steps 1000 --batch 256 --seq 4096 --ckpt-dir /ckpt/qwen2

On a real multi-host TPU pod this process runs per-host under
``jax.distributed.initialize()`` (launched by GKE/xpk/ray); the mesh maps
over all global devices. On this CPU container it runs the same code over
host devices with ``--smoke`` reduced configs.

Fault tolerance: step-atomic checkpoints + LATEST pointer; on restart the
trainer resumes from the last checkpoint and the step-keyed data stream
replays identically (see train/trainer.py).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 pod mesh (needs 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.production_mesh or args.multi_pod:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh()

    dc = DataConfig(seed=0, batch_size=args.batch, seq_len=args.seq,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=args.steps, log_every=10,
                     ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                     grad_accum=args.accum)
    oc = OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                   total_steps=args.steps)
    tr = Trainer(cfg, mesh, dc, tc, oc)
    if tr.step:
        print(f"resumed at step {tr.step}")
    tr.run(on_metrics=lambda s, m: print(
        f"step {s} loss {m['loss']:.4f} gnorm {m['grad_norm']:.2f}",
        flush=True))


if __name__ == "__main__":
    main()
