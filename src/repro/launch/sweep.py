"""Resumable dry-run sweep over every (arch × shape × mesh) cell.

Each cell runs in a fresh subprocess (its own XLA device-count env and
memory lifetime); completed cells are skipped on re-run, so the sweep
survives interruption — run it, kill it, run it again.

    PYTHONPATH=src python -m repro.launch.sweep [--mesh single multi]
        [--archs a b c] [--shapes s1 s2] [--out experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.configs import SHAPES, list_archs

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def run_sweep(archs, shapes, meshes, out: str, analysis: bool = True,
              force: bool = False) -> dict:
    outdir = Path(out)
    outdir.mkdir(parents=True, exist_ok=True)
    results = {}
    todo = [(a, s, m) for a in archs for s in shapes for m in meshes]
    for i, (arch, shape, mesh) in enumerate(todo):
        name = f"{arch}_{shape}_{mesh}.json"
        path = outdir / name
        if path.exists() and not force:
            rec = json.loads(path.read_text())
            if rec.get("status") in ("ok", "skipped"):
                results[name] = rec["status"]
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", str(outdir)]
        # the roofline table is single-pod only; multi-pod cells prove
        # the pod-axis sharding compiles — skip the analysis probes there
        if not analysis or mesh == "multi":
            cmd.append("--no-analysis")
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh} ...",
              flush=True)
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=7200)
        dt = time.time() - t0
        if proc.returncode != 0:
            results[name] = "FAILED"
            (outdir / name).write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "status": "failed",
                "stderr": proc.stderr[-4000:],
            }, indent=1))
            print(f"    FAILED in {dt:.0f}s\n{proc.stderr[-2000:]}",
                  flush=True)
        else:
            rec = json.loads(path.read_text())
            results[name] = rec["status"]
            print(f"    {rec['status']} in {dt:.0f}s", flush=True)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=list(SHAPE_ORDER))
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = args.archs or [a for a in list_archs() if a != "llama2-7b"]
    res = run_sweep(archs, args.shapes, args.mesh, args.out,
                    analysis=not args.no_analysis, force=args.force)
    ok = sum(1 for v in res.values() if v in ("ok", "skipped"))
    print(f"\n{ok}/{len(res)} cells green")
    bad = {k: v for k, v in res.items() if v not in ("ok", "skipped")}
    if bad:
        print("failures:", json.dumps(bad, indent=1))
        sys.exit(1)


if __name__ == "__main__":
    main()
