"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The single-pod mesh
is a v5e-256 pod as 16×16 ("data", "model"); the multi-pod mesh stacks 2
pods on a leading "pod" axis (DCN data-parallel domain).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return compat.make_mesh((data, model), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


@dataclasses.dataclass(frozen=True)
class ServingMesh:
    """Device layout of the multi-device paged serving path (DESIGN.md
    §13). ``mesh`` is the decode-pool (or unified) ("data", "model")
    mesh the Scheduler shards its KV pools over; ``prefill_mesh`` is the
    disaggregated prefill pool when one was carved out (None = unified
    serving: prefill and decode interleave on ``mesh``)."""
    mesh: object
    prefill_mesh: Optional[object] = None

    @property
    def disaggregated(self) -> bool:
        return self.prefill_mesh is not None


def _submesh(devs, data: int, model: int):
    return jax.sharding.Mesh(
        np.asarray(devs, dtype=object).reshape(data, model),
        ("data", "model"))


def make_serving_mesh(data: Optional[int] = None, model: int = 1, *,
                      prefill_data: int = 0, devices=None) -> ServingMesh:
    """Serving mesh(es) over the host's devices.

    Unified (``prefill_data=0``): one (data × model) mesh over the first
    data·model devices. Disaggregated: the FIRST ``prefill_data``·model
    devices become the prefill pool and the next data·model devices the
    decode pool — two disjoint meshes whose "data" axes should normally
    match so a handed-off KV block's shard moves straight to its
    counterpart device (`serve.paged.disagg`, never crossing the data
    axis). ``data=None`` uses every remaining device."""
    devs = list(devices if devices is not None else jax.devices())
    pre = None
    if prefill_data:
        need = prefill_data * model
        assert len(devs) > need, (len(devs), need)
        pre = _submesh(devs[:need], prefill_data, model)
        devs = devs[need:]
    if data is None:
        data = len(devs) // model
    assert data * model <= len(devs), (data, model, len(devs))
    return ServingMesh(mesh=_submesh(devs[:data * model], data, model),
                       prefill_mesh=pre)
