"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The single-pod mesh
is a v5e-256 pod as 16×16 ("data", "model"); the multi-pod mesh stacks 2
pods on a leading "pod" axis (DCN data-parallel domain).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return compat.make_mesh((data, model), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
