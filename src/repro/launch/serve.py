"""Production serving launcher: batched generation with the paper's
deployment configuration (W4A8 WS-OCS weights, LUT group softmax, fused
norms, RCW weight streaming).

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --batch 8 --new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Engine, ServeConfig, quantize_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.no_quant:
        cfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True,
                          use_fusion=True, dataflow="ws_ocs", rcw=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if not args.no_quant:
        params = quantize_params(params, cfg)

    eng = Engine(cfg, params, max_len=args.prompt_len + args.new + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, ServeConfig(max_new_tokens=args.new,
                                            temperature=args.temperature))
    dt = time.perf_counter() - t0
    print(f"{args.batch} requests × {args.new} new tokens in {dt:.2f}s "
          f"({args.batch*args.new/dt:.1f} tok/s incl compile)")
    print("first output:", out[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
