"""Production serving launcher: batched generation with the paper's
deployment configuration (W4A8 WS-OCS weights, LUT group softmax, fused
norms, RCW weight streaming).

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --batch 8 --new 32

The paged engine (``--paged``, DESIGN.md §10–§13) is the multi-device
default: it shards the KV block pools over every visible device's
"data" axis (``--data`` overrides the count; outputs stay
token-identical to single-device). ``--prefill-data N`` carves N
devices into a disaggregated prefill pool that hands finished prompts
to the decode pool:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch llama2-7b --smoke --paged \
        --data 4 --prefill-data 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Engine, ServeConfig, quantize_params


def _run_paged(cfg, params, args) -> None:
    from repro import obs
    from repro.launch.mesh import make_serving_mesh
    from repro.serve.batching import Request
    from repro.serve.paged import DisaggScheduler, Scheduler

    max_len = args.prompt_len + args.new + 1
    max_len += -max_len % 16                     # block-size align
    n_dev = len(jax.devices())
    data = args.data or max(n_dev - args.prefill_data, 1)
    sm = make_serving_mesh(data=data, prefill_data=args.prefill_data) \
        if n_dev > 1 else None
    mesh = sm.mesh if sm is not None else None
    # --trace-out/--metrics-out force telemetry on for this run; without
    # them the schedulers fall back to the env-gated defaults
    # (REPRO_TRACE/REPRO_METRICS), off = zero-cost no-ops (§15)
    trace = obs.Tracer(enabled=True) if args.trace_out else None
    metrics = obs.Metrics(enabled=True) if args.metrics_out else None
    kw = dict(slots=args.slots, max_len=max_len, trace=trace,
              metrics=metrics)
    extra = {} if args.num_blocks is None else \
        {"num_blocks": args.num_blocks}
    if sm is not None and sm.disaggregated:
        sched = DisaggScheduler(cfg, params, prefill_mesh=sm.prefill_mesh,
                                decode_mesh=mesh, **kw,
                                prefill_kw=extra, decode_kw=extra)
        stats = sched.decode
    else:
        sched = Scheduler(cfg, params, mesh=mesh, **kw, **extra)
        stats = sched
    rng = np.random.default_rng(0)
    for rid in range(args.batch):
        prompt = rng.integers(2, cfg.vocab_size,
                              size=args.prompt_len).tolist()
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.new))
    t0 = time.perf_counter()
    out = sched.run()
    dt = time.perf_counter() - t0
    rep = stats.stream_amortization_report()
    print(f"paged ({'disagg' if sm is not None and sm.disaggregated else 'unified'}, "
          f"data_shards={stats.data_shards()}): "
          f"{args.batch} requests × {args.new} new tokens in {dt:.2f}s "
          f"({args.batch*args.new/dt:.1f} tok/s incl compile)")
    print(f"modeled amortized tok/s {rep['amortized_tokens_per_s']:.0f} "
          f"@ mean_active {rep['mean_active']:.1f}; "
          f"peak KV blocks {stats.pool.peak_in_use} "
          f"({stats.per_device_peak_blocks():.1f}/device)")
    print("first output:", out[0])

    tr = trace if trace is not None else stats.trace
    mt = metrics if metrics is not None else stats.metrics
    if tr.enabled and args.trace_out:
        doc = tr.export_chrome(args.trace_out)
        counts = obs.validate_chrome_trace(doc)
        print(f"trace: {counts['spans']} spans / {counts['events']} "
              f"events ({counts['lanes']} lanes) -> {args.trace_out}")
    if mt.enabled:
        if args.census:
            # fold per-phase kernel-dispatch counts (jaxpr tracing costs
            # seconds — opt-in) so the export carries dispatch shape
            # next to the timing histograms
            eng = Engine(cfg, params, max_len=max_len)
            for phase in ("decode", "prefill"):
                obs.fold_census(mt, eng.dispatch_census(phase), phase)
        print(mt.summary())
        print(obs.format_report(obs.drift_report(
            mt, chunk=32, ctx=max_len, params=params)))
        if args.metrics_out:
            mt.export_prometheus(args.metrics_out)
            print(f"metrics -> {args.metrics_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--paged", action="store_true",
                    help="paged scheduler; multi-device when >1 device")
    ap.add_argument("--data", type=int, default=0,
                    help="decode-pool data-axis size (0 = all devices)")
    ap.add_argument("--prefill-data", type=int, default=0,
                    help="devices carved into a disaggregated prefill pool")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of the paged run here")
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus-text metrics of the paged run here")
    ap.add_argument("--census", action="store_true",
                    help="fold per-phase kernel-dispatch counts into the "
                         "metrics export (traces jaxprs; costs seconds)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.no_quant:
        cfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True,
                          use_fusion=True, dataflow="ws_ocs", rcw=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if not args.no_quant:
        params = quantize_params(params, cfg)

    if args.paged:
        _run_paged(cfg, params, args)
        return

    eng = Engine(cfg, params, max_len=args.prompt_len + args.new + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, ServeConfig(max_new_tokens=args.new,
                                            temperature=args.temperature))
    dt = time.perf_counter() - t0
    print(f"{args.batch} requests × {args.new} new tokens in {dt:.2f}s "
          f"({args.batch*args.new/dt:.1f} tok/s incl compile)")
    print("first output:", out[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
