"""Training loop: jitted step with sharded params/optimizer, gradient
accumulation, checkpoint/restart, and failure recovery.

Fault-tolerance model (designed for 1000+ nodes, exercised in tests):
  * checkpoints are step-atomic and elastic (train/checkpoint.py) — a
    failed node set restarts from LATEST on any mesh shape;
  * the data stream is step-keyed (data/pipeline.py) so the restored run
    consumes exactly the batches the lost run would have;
  * a watchdog wraps each step: on exception the step is retried once
    (transient), then the trainer rolls back to LATEST (fail-stop model —
    the launcher re-schedules dead hosts; in-process we simulate this);
  * straggler mitigation at this layer = deterministic work partitioning
    (no dynamic host work) + checkpoint cadence bounding lost work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

import numpy as np

from repro import compat
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as st
from repro.models import api
from repro.parallel import sharding as sh
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    grad_accum: int = 1
    max_step_retries: int = 1


def make_accum_train_step(cfg: ModelConfig, oc: OptConfig, accum: int):
    """Gradient accumulation: scan over microbatches, deferring the
    (cross-data/pod) gradient reduction to a single reduce at the end —
    the collective-deferral trick (one all-reduce per step, not per
    microbatch)."""
    from repro.train.optimizer import adamw_update

    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            acc = carry
            loss, grads = jax.value_and_grad(api.loss_fn)(params, cfg, mb)
            acc = jax.tree.map(jnp.add, acc,
                               jax.tree.map(lambda g: g / accum, grads))
            return acc, loss

        micro_batches = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, losses = jax.lax.scan(micro, zeros, micro_batches)
        params2, opt2, metrics = adamw_update(params, grads, opt_state, oc)
        metrics["loss"] = jnp.mean(losses)
        return params2, opt2, metrics

    return train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, dc: DataConfig,
                 tc: TrainConfig, oc: Optional[OptConfig] = None,
                 rules=None):
        self.cfg, self.mesh, self.dc, self.tc = cfg, mesh, dc, tc
        self.oc = oc or st.default_opt_config(cfg)
        self.rules = rules or sh.train_rules()
        self.data = SyntheticLM(dc, cfg)
        self.step = 0

        with compat.set_mesh(mesh):
            self.p_sh = st.param_shardings(cfg, mesh, self.rules)
            self.o_sh = st.opt_shardings(cfg, mesh, self.rules, self.oc)
            params_h = api.init(jax.random.PRNGKey(dc.seed), cfg)
            self.params = jax.device_put(params_h, self.p_sh)
            self.opt_state = jax.device_put(
                init_opt_state(params_h, self.oc), self.o_sh)
            fn = (make_accum_train_step(cfg, self.oc, tc.grad_accum)
                  if tc.grad_accum > 1 else st.make_train_step(cfg, self.oc))
            self._step_fn = jax.jit(
                fn, in_shardings=(self.p_sh, self.o_sh, None),
                donate_argnums=(0, 1))

        # resume if a checkpoint exists
        if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
            self.restore()

    # -- fault-tolerance surface -------------------------------------
    def save(self):
        assert self.tc.ckpt_dir
        ckpt.save(self.tc.ckpt_dir, self.step, self.params, self.opt_state,
                  extra={"data_seed": self.dc.seed})
        ckpt.prune_old(self.tc.ckpt_dir, self.tc.ckpt_keep)

    def restore(self, step: Optional[int] = None):
        assert self.tc.ckpt_dir
        params, opt, manifest = ckpt.restore(
            self.tc.ckpt_dir, step, self.params, self.opt_state,
            shardings=(self.p_sh, self.o_sh))
        self.params, self.opt_state = params, opt
        self.step = manifest["step"]
        return self.step

    # -- loop ----------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None):
        steps = steps if steps is not None else self.tc.total_steps
        target = self.step + steps
        with compat.set_mesh(self.mesh):
            while self.step < target:
                batch = self.data.batch_at(self.step)
                batch = jax.tree.map(jnp.asarray, batch)
                retries = 0
                while True:
                    try:
                        self.params, self.opt_state, metrics = \
                            self._step_fn(self.params, self.opt_state, batch)
                        break
                    except Exception:
                        retries += 1
                        if retries > self.tc.max_step_retries:
                            if self.tc.ckpt_dir and \
                                    ckpt.latest_step(self.tc.ckpt_dir) is not None:
                                self.restore()   # roll back and continue
                                batch = jax.tree.map(
                                    jnp.asarray, self.data.batch_at(self.step))
                                retries = 0
                                continue
                            raise
                self.step += 1
                if on_metrics and self.step % self.tc.log_every == 0:
                    on_metrics(self.step,
                               jax.tree.map(lambda x: float(x), metrics))
                if self.tc.ckpt_dir and self.step % self.tc.ckpt_every == 0:
                    self.save()
        return self.params
