"""Step-atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json      — step, data state, tree structure, dtypes
            arrays.npz         — flat param + optimizer arrays
         <dir>/LATEST          — atomically updated pointer

Fault-tolerance properties:
  * atomic publish: a checkpoint becomes visible only after its manifest
    and arrays are fully written (tmp-dir rename + LATEST pointer last);
  * elastic restore: arrays are saved mesh-agnostic (host layout) and
    re-device_put with whatever NamedShardings the *new* mesh derives
    from the logical axes — resume on any pod count / mesh shape;
  * data-pipeline state (step, seed) rides in the manifest, and the
    step-keyed synthetic stream replays identically after resume.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax

from repro import compat
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = compat.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, params, opt_state,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Write one atomic checkpoint; returns the published path."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    state = {"params": params, "opt": opt_state}
    flat, _ = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))

    final = root / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                    # atomic publish
    (root / "LATEST.tmp").write_text(str(step))
    os.rename(root / "LATEST.tmp", root / "LATEST")
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str, step: Optional[int], params_like, opt_like,
            shardings: Optional[Tuple] = None):
    """Restore (params, opt_state, manifest). ``params_like``/``opt_like``
    give the tree structure (abstract or concrete). ``shardings`` is an
    optional (param_shardings, opt_shardings) pair for elastic placement
    onto the current mesh."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    state_like = {"params": params_like, "opt": opt_like}
    flat_like, treedef = _flatten_with_paths(state_like)
    keys = sorted(flat_like.keys())
    assert keys == manifest["keys"], "checkpoint/model structure mismatch"
    leaves = [data[k] for k in keys]
    # restore in treedef leaf order (flatten_with_path order == sorted-ish
    # by construction: rebuild via dict)
    by_key = dict(zip(keys, leaves))
    ordered = [by_key[k] for k, _ in sorted(flat_like.items())]
    # map back: flatten order of tree.flatten matches flatten_with_path
    flat_order = [k for k, _ in _iter_in_flatten_order(state_like)]
    ordered = [by_key[k] for k in flat_order]
    state = jax.tree.unflatten(jax.tree.structure(state_like), ordered)

    if shardings is not None:
        p_sh, o_sh = shardings
        state["params"] = jax.device_put(state["params"], p_sh)
        state["opt"] = jax.device_put(state["opt"], o_sh)
    return state["params"], state["opt"], manifest


def _iter_in_flatten_order(tree):
    flat, _ = compat.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    root = Path(ckpt_dir)
    steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(root / f"step_{s}", ignore_errors=True)
