"""AdamW + LR schedules, implemented directly on pytrees (no optax here —
the substrate is part of the deliverable).

Optimizer-state dtype is configurable: fp32 default; bf16 for the
480B-class config where fp32 m/v would not fit the pod (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: jnp.dtype = jnp.float32


def lr_at(step: jax.Array, oc: OptConfig) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = oc.lr * step / max(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.lr * (oc.min_lr_ratio + (1 - oc.min_lr_ratio)
                   * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, oc: OptConfig) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, oc.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, opt_state: Dict,
                 oc: OptConfig) -> Tuple[Dict, Dict, Dict]:
    """One AdamW step. Returns (params, opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def new_m_fn(g, m):
        return (b1 * m.astype(jnp.float32)
                + (1 - b1) * g.astype(jnp.float32)).astype(oc.state_dtype)

    def new_v_fn(g, v):
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g.astype(jnp.float32))) \
            .astype(oc.state_dtype)

    def new_p_fn(p, m2, v2):
        mhat = m2.astype(jnp.float32) / bc1
        vhat = v2.astype(jnp.float32) / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_m = jax.tree.map(new_m_fn, grads, opt_state["m"])
    new_v = jax.tree.map(new_v_fn, grads, opt_state["v"])
    new_params = jax.tree.map(new_p_fn, params, new_m, new_v)
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
