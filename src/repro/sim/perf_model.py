"""Analytical RCW-CIM performance model — reproduces the paper's headline
numbers (Table II, Fig 8, Fig 9) from first-principles components plus a
small number of FITTED constants (each listed below with its physical
interpretation). Where the paper's figures are mutually over-determining,
the residual to the published number is reported by the benchmarks rather
than hidden (all within ~1.5 pp).

Fitted constants (derived in EXPERIMENTS.md §Paper-validation):
  * CIM_WRITE_BW      = 102.4 GB/s — multi-macro parallel weight-update
    rate, provisioned to match the dual-DDR5 stream (32 macros ×
    32 B/cycle @ 100 MHz); the decode-time update cost RCW hides.
  * STALL_WRITE_BW    ≈ 6.08 GB/s — baseline (non-RCW) *array-stall*
    write rate during prefill: without RCW the array cannot compute while
    being written, so each WS-OS weight re-load stalls the MACs.
  * NL_FUSED_RATE     ≈ 11.7 FP16 elems/cycle — group softmax/RMSNorm
    with LUT-64 + partial accumulation across 8 banks.
  * NL_BASE_RATE      ≈ 0.227 elems/cycle — prior-work CIM nonlinear path
    (full accumulation only, global dependencies).
  * MAC_UTIL          = 0.94 — prefill MXU/array utilization.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.core.dataflow import Dataflow, TileConfig, access_counts
from repro.core.rcw import latency_rcw, latency_serial, RCWStage
from repro.sim.chip import RCWCIM, RCWCIMChip

CIM_WRITE_BW = 102.4e9   # provisioned to match the dual-DDR5 stream rate
STALL_WRITE_BW = 6.083e9
NL_FUSED_RATE = 11.7
NL_BASE_RATE = 0.227
MAC_UTIL = 0.94

# WS-OCS tile geometry fitted to Fig 8 (m from the 87.6 % update claim,
# k=n=256 = bank geometry; gives 50.4 % vs the published 51.6 %):
TILE_M, TILE_N, TILE_K = 128, 256, 256


@dataclasses.dataclass(frozen=True)
class LlamaGeom:
    """Llama2-7B GEMM set: (N=d_in, K=d_out, count per layer)."""
    layers: int = 32
    d_model: int = 4096
    d_ff: int = 11008
    vocab: int = 32000
    heads: int = 32

    @property
    def gemms(self) -> List[Tuple[int, int, int]]:
        d, f = self.d_model, self.d_ff
        return [(d, d, 4), (d, f, 2), (f, d, 1)]

    @property
    def matmul_params(self) -> int:
        return self.layers * sum(n * k * c for n, k, c in self.gemms)

    def weight_bytes(self, bits: int = 4) -> float:
        return self.matmul_params * bits / 8

    def nl_elems_per_token(self, ctx: int = 1024) -> float:
        d, f = self.d_model, self.d_ff
        per_layer = 2 * d * 2 + self.heads * ctx + f   # 2×RMSNorm, softmax, SiLU
        return self.layers * per_layer + d


GEOM = LlamaGeom()


# ---------------------------------------------------------------------------
# Component times
# ---------------------------------------------------------------------------

def t_dram_weights(chip: RCWCIMChip = RCWCIM, bits: int = 4) -> float:
    return GEOM.weight_bytes(bits) / (chip.dram_gbps * 1e9)


def t_mac_per_token(chip: RCWCIMChip = RCWCIM) -> float:
    return 2 * GEOM.matmul_params / chip.peak_ops_per_s


def t_nl_per_token(fused: bool, ctx: int = 1024,
                   chip: RCWCIMChip = RCWCIM) -> float:
    rate = NL_FUSED_RATE if fused else NL_BASE_RATE
    return GEOM.nl_elems_per_token(ctx) / (rate * chip.freq_hz)


# ---------------------------------------------------------------------------
# Decode (per-token) latency — Fig 9(b)
# ---------------------------------------------------------------------------

def decode_latency(rcw: bool, fusion: bool, ctx: int = 1024,
                   chip: RCWCIMChip = RCWCIM,
                   write_bw: float = None) -> float:
    """Per-token decode latency. Baseline (no RCW): DRAM stream, CIM
    write, MAC, and nonlinear all serialize. RCW's Phase-2 concurrency
    overlaps the CIM write with the DRAM stream (streaming write) and
    with MAC + NL execution, leaving max(stream, write) + compute;
    fusion switches the NL path to the group/LUT/partial-accum rate."""
    t_dram = t_dram_weights(chip)
    t_upd = GEOM.weight_bytes() / (write_bw or CIM_WRITE_BW)
    t_mac = t_mac_per_token(chip)
    t_nl = t_nl_per_token(fusion, ctx, chip)
    if rcw:
        return max(t_dram, t_upd) + t_mac + t_nl
    return t_dram + t_upd + t_mac + t_nl


def decode_tokens_per_s(rcw: bool = True, fusion: bool = True,
                        ctx: int = 1024) -> float:
    return 1.0 / decode_latency(rcw, fusion, ctx)


# ---------------------------------------------------------------------------
# Batched-decode weight-stream amortization (DESIGN.md §10)
# ---------------------------------------------------------------------------

def amortized_decode_latency(n_active: int, rcw: bool = True,
                             fusion: bool = True, ctx: int = 1024,
                             chip: RCWCIMChip = RCWCIM,
                             write_bw: float = None) -> float:
    """Per-REQUEST decode latency when one weight stream serves
    ``n_active`` concurrent requests. The RCW-bound stream term (DRAM
    weight stream overlapped with the CIM update) is paid once per tick
    regardless of batch size — continuous batching divides it across the
    active slots — while MAC and nonlinear work scale per token. This is
    the denominator the paged scheduler's admission/occupancy policy
    maximizes (its per-tick active counts feed
    ``scheduler_amortization_report``)."""
    assert n_active >= 1, n_active
    t_dram = t_dram_weights(chip)
    t_upd = GEOM.weight_bytes() / (write_bw or CIM_WRITE_BW)
    stream = max(t_dram, t_upd) if rcw else t_dram + t_upd
    return stream / n_active + t_mac_per_token(chip) \
        + t_nl_per_token(fusion, ctx, chip)


def expected_tokens_per_pass(k: int, accept_rate: float) -> float:
    """E[tokens emitted per verify pass] under greedy acceptance with k
    drafts and per-position draft-match probability ``accept_rate``:
    the accepted prefix length a is geometric-truncated, P(a) =
    α^a(1-α) for a<k and α^k at a=k, and every pass emits a+1 tokens
    (accepted drafts + the target's bonus token), giving the closed
    form (1-α^{k+1})/(1-α)."""
    assert k >= 1, k
    a = float(accept_rate)
    assert 0.0 <= a <= 1.0, a
    if a == 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculative_decode_latency(n_active: int, k: int, accept_rate: float,
                               rcw: bool = True, fusion: bool = True,
                               ctx: int = 1024, chip: RCWCIMChip = RCWCIM,
                               write_bw: float = None) -> float:
    """Per-EMITTED-token decode latency with k-draft speculation on top
    of continuous batching (DESIGN.md §12). One verify pass still pays
    the RCW-bound weight stream once (divided across ``n_active`` slots,
    exactly as in ``amortized_decode_latency``) but emits
    ``expected_tokens_per_pass(k, accept_rate)`` tokens per slot —
    speculation multiplies the stream amortization's numerator where
    batching grows its denominator. The price: MAC and nonlinear work
    run for all k+1 verified positions regardless of how many are
    accepted, so those terms inflate by (k+1)/E — at low acceptance the
    wasted lanes overtake the stream saving, which is the crossover the
    BENCH_pr7 acceptance sweep locates empirically. Draft cost is not
    modeled (the oracle-draft benchmark measures exactly this bound)."""
    assert n_active >= 1, n_active
    e = expected_tokens_per_pass(k, accept_rate)
    t_dram = t_dram_weights(chip)
    t_upd = GEOM.weight_bytes() / (write_bw or CIM_WRITE_BW)
    stream = max(t_dram, t_upd) if rcw else t_dram + t_upd
    per_pass = stream / n_active + (k + 1) * (
        t_mac_per_token(chip) + t_nl_per_token(fusion, ctx, chip))
    return per_pass / e


def scheduler_amortization_report(active_counts, rcw: bool = True,
                                  fusion: bool = True,
                                  ctx: int = 1024,
                                  prefill_counts=None) -> Dict[str, float]:
    """Realized weight-stream amortization for a scheduler run.
    ``active_counts`` is the per-decode-tick number of active slots
    (``serve.paged.Scheduler.tick_active``). Returns the occupancy, the
    modeled amortized throughput, and the speedup over serving the same
    tokens at batch 1 (where every token pays the full stream).

    ``prefill_counts`` (``Scheduler.tick_prefill``) is the per-tick
    number of chunk-prefill kernel launches — one per prefilling slot,
    each a single ``paged_flash_prefill`` dispatch since PR 6. The
    report measures prefill batching the same way decode amortization
    is measured: mean launches per prefill tick is the occupancy of the
    prefill phase of the interleaved schedule (DESIGN.md §11)."""
    counts = [int(c) for c in active_counts if c > 0]
    pre = [int(c) for c in (prefill_counts or []) if c > 0]
    prefill = {
        "prefill_ticks": len(pre),
        "prefill_launches": sum(pre),
        "mean_prefill_launches": (sum(pre) / len(pre)) if pre else 0.0,
    }
    if not counts:
        return {"ticks": 0, "tokens": 0, "mean_active": 0.0,
                "amortized_tokens_per_s": 0.0, "speedup_vs_b1": 1.0,
                **prefill}
    tokens = sum(counts)
    total_t = sum(n * amortized_decode_latency(n, rcw, fusion, ctx)
                  for n in counts)
    b1 = decode_latency(rcw, fusion, ctx)
    return {
        "ticks": len(counts),
        "tokens": tokens,
        "mean_active": tokens / len(counts),
        "amortized_tokens_per_s": tokens / total_t,
        "speedup_vs_b1": (tokens * b1) / total_t,
        **prefill,
    }


def chunk_prefill_residency_report(chunk: int = 32, prefix_tokens: int = 1024,
                                   max_len: int = 4096, block_size: int = 16,
                                   chip: RCWCIMChip = RCWCIM
                                   ) -> Dict[str, float]:
    """Chunk-prefill kernel-residency row (DESIGN.md §11): HBM traffic
    for one chunk's attention, dense-oracle vs kernel-resident.

    The PR 5 oracle gathered the block pool into a dense
    ``(NBMAX·BS, Hkv, D)`` prefix copy per layer — a write + read-back
    round trip over the VIRTUAL length ``max_len`` regardless of how few
    tokens were actually written — then materialized the ``(C, max_len)``
    score matrix. The paged flash-prefill kernel streams only the
    written-prefix blocks through VMEM once (block-level causal skip
    prunes table slots past the prefix) and keeps scores in scratch, so
    its traffic scales with ``prefix_tokens + chunk``, not ``max_len``.
    FP16 KV, FP32 scores; per-layer bytes × the Llama GEOM layer count."""
    H = GEOM.heads
    D = GEOM.d_model // H
    kv_tok = 2 * H * D * 2                       # K+V rows, FP16 bytes
    written = min(-(-(prefix_tokens + chunk) // block_size) * block_size,
                  max_len)
    dense = GEOM.layers * (2 * max_len * kv_tok          # densify + read
                           + 2 * chunk * max_len * H * 4)  # scores out+in
    resident = GEOM.layers * written * kv_tok            # stream once
    bw = chip.dram_gbps * 1e9
    return {
        "chunk": chunk, "prefix_tokens": prefix_tokens, "max_len": max_len,
        "dense_oracle_bytes": float(dense),
        "kernel_resident_bytes": float(resident),
        "traffic_reduction": 1 - resident / dense,
        "dense_oracle_ms": dense / bw * 1e3,
        "kernel_resident_ms": resident / bw * 1e3,
    }


# ---------------------------------------------------------------------------
# Multi-device serving (DESIGN.md §13)
# ---------------------------------------------------------------------------

def sharded_kv_scaleout_report(data: int, per_device_blocks: int,
                               tokens_per_slot: int = 256,
                               block_size: int = 16,
                               rcw: bool = True, fusion: bool = True,
                               ctx: int = 1024) -> Dict[str, float]:
    """What sharding the KV pool over ``data`` devices buys (DESIGN.md
    §13): each device holds a 1/data slice of every block, so a fixed
    per-device block budget aggregates to ``data×`` KV capacity, which
    admits ``data×`` concurrent decode slots — and concurrent slots are
    the DENOMINATOR of the RCW weight-stream amortization. Compute is
    replicated (this layout trades FLOPs for stream amortization and KV
    capacity, the binding resources on RCW-CIM); the model therefore
    scales only the amortization term, not MAC/NL."""
    assert data >= 1 and per_device_blocks >= 1
    blocks_per_slot = -(-tokens_per_slot // block_size) + 1
    slots = max((data * per_device_blocks) // blocks_per_slot, 1)
    slots_1dev = max(per_device_blocks // blocks_per_slot, 1)
    lat = amortized_decode_latency(slots, rcw, fusion, ctx)
    lat_1 = amortized_decode_latency(slots_1dev, rcw, fusion, ctx)
    return {
        "data": data,
        "per_device_blocks": per_device_blocks,
        "concurrent_slots": slots,
        "tokens_per_s": slots / lat,
        "tokens_per_s_1dev": slots_1dev / lat_1,
        "scaling_vs_1dev": (slots / lat) / (slots_1dev / lat_1),
    }


def disaggregated_serving_report(n_requests: int = 16,
                                 prompt_tokens: int = 1024,
                                 new_tokens: int = 64,
                                 decode_slots: int = 16,
                                 kv_handoff_bytes: float = None,
                                 interconnect_gbps: float = 50.0,
                                 rcw: bool = True, fusion: bool = True,
                                 chip: RCWCIMChip = RCWCIM
                                 ) -> Dict[str, float]:
    """Projected gain of disaggregated prefill/decode pools over unified
    interleaved serving (DESIGN.md §13). Unified: every prefill chunk
    stalls all decode slots, so wall-clock ≈ prefill + decode serialized.
    Disaggregated: the pools overlap in steady state — wall-clock ≈
    max(prefill, decode) + the KV handoff transfer (per-request KV bytes
    over the interconnect; defaults to FP16 K+V for ``prompt_tokens``
    over the Llama GEOM). The host CPU testbed serializes the two pools
    (one process), so this projection — not wall-clock — is the BENCH
    row for the disaggregated arm; tests assert token identity instead."""
    d_head = GEOM.d_model // GEOM.heads
    if kv_handoff_bytes is None:
        kv_handoff_bytes = (2 * GEOM.layers * prompt_tokens
                            * GEOM.heads * d_head * 2)     # K+V, FP16
    t_pre = n_requests * prefill_latency(Dataflow.WS_OCS, prompt_tokens,
                                         rcw=rcw, chip=chip)
    t_dec = n_requests * new_tokens \
        * amortized_decode_latency(decode_slots, rcw, fusion,
                                   ctx=prompt_tokens, chip=chip)
    t_xfer = n_requests * kv_handoff_bytes / (interconnect_gbps * 1e9)
    unified = t_pre + t_dec
    disagg = max(t_pre, t_dec) + t_xfer
    return {
        "prefill_s": t_pre,
        "decode_s": t_dec,
        "handoff_s": t_xfer,
        "handoff_bytes_per_req": float(kv_handoff_bytes),
        "unified_s": unified,
        "disagg_s": disagg,
        "speedup": unified / disagg,
        "tokens_per_s_unified": n_requests * new_tokens / unified,
        "tokens_per_s_disagg": n_requests * new_tokens / disagg,
    }


# ---------------------------------------------------------------------------
# Prefill — Fig 9(a), Fig 8
# ---------------------------------------------------------------------------

def prefill_dram_bytes(df: Dataflow, tokens: int = 1024,
                       weight_scale: float = 1.0) -> float:
    """External DRAM bytes for one 1024-token prefill (Table-I formulas
    over the Llama GEMM set; INT8 activations, INT4 weights).
    ``weight_scale`` shrinks the weight-stream term only — the N:M
    compression factor from ``sparse_weight_factor`` (§14)."""
    total = 0.0
    for N, K, cnt in GEOM.gemms:
        tc = TileConfig(M=tokens, N=N, K=K,
                        m=min(TILE_M, tokens), n=min(TILE_N, N),
                        k=min(TILE_K, K))
        c = access_counts(df, tc)
        total += (c["input"] * 1.0 + c["weight"] * 0.5 * weight_scale
                  + c["output"] * 1.0) * cnt * GEOM.layers
    return total


def prefill_update_bytes(df: Dataflow, tokens: int = 1024,
                         weight_scale: float = 1.0) -> float:
    total = 0.0
    for N, K, cnt in GEOM.gemms:
        tc = TileConfig(M=tokens, N=N, K=K,
                        m=min(TILE_M, tokens), n=min(TILE_N, N),
                        k=min(TILE_K, K))
        total += access_counts(df, tc)["cim_update"] * 0.5 * weight_scale \
            * cnt * GEOM.layers
    return total


def prefill_latency(df: Dataflow, tokens: int = 1024, rcw: bool = True,
                    chip: RCWCIMChip = RCWCIM) -> float:
    """Prefill latency for `tokens`. Compute overlaps DRAM streaming
    (double-buffered input/psum), so latency = max(MAC, DRAM) + exposed
    weight-update stalls. With RCW + WS-OCS the NK update stream hides
    behind compute; without RCW every update stalls the array at the
    fitted STALL_WRITE_BW."""
    t_mac = t_mac_per_token(chip) * tokens / MAC_UTIL
    t_dram = prefill_dram_bytes(df, tokens) / (chip.dram_gbps * 1e9)
    upd = prefill_update_bytes(df, tokens)
    if rcw and df == Dataflow.WS_OCS:
        exposed = 0.0                       # NK stream ≪ compute; hidden
    else:
        exposed = upd / STALL_WRITE_BW
    return max(t_mac, t_dram) + exposed


def prefill_per_token_ms(tokens: int = 1024) -> float:
    return prefill_latency(Dataflow.WS_OCS, tokens) / tokens * 1e3


# ---------------------------------------------------------------------------
# Structured N:M weight sparsity (DESIGN.md §14)
# ---------------------------------------------------------------------------

def sparse_weight_factor(n: int, m: int, granularity: str = "col",
                         bits: int = 4, k: int = None) -> float:
    """Compressed weight-stream bytes as a fraction of the dense stream.
    'col' stores n/m of the values plus a 1-bit-per-element keep bitmask
    (w4 2:4 → (2+1)/4 = 0.75, the 25 % panel-DMA saving the sparse RCW
    kernel realizes per K-tile); 'row' keeps whole rows, whose int32
    kept-row indices amortize over the K columns of each row and are
    negligible at model-sized K."""
    assert 0 < n < m, (n, m)
    val = bits * n / m
    if granularity == "col":
        meta = 1.0
    else:
        meta = 32.0 * (n / m) / float(k or GEOM.d_model)
    return (val + meta) / bits


def sparse_weight_bytes(n: int, m: int, granularity: str = "col",
                        bits: int = 4) -> float:
    """Compressed matmul-weight footprint (values + N:M metadata)."""
    return GEOM.weight_bytes(bits) \
        * sparse_weight_factor(n, m, granularity, bits)


def sparse_decode_latency(n: int, m: int, granularity: str = "col",
                          rcw: bool = True, fusion: bool = True,
                          ctx: int = 1024, chip: RCWCIMChip = RCWCIM,
                          bits: int = 4) -> float:
    """Per-token decode latency with N:M-compressed weight streaming on a
    sparsity-gated CIM array: the DRAM stream and the CIM update both
    shrink by ``sparse_weight_factor`` (only nonzero groups + metadata
    cross the chip boundary or get written), and the MAC term scales by
    the n/m keep fraction (zero weight groups never enter the array, so
    their MACs are skipped — the paper's structured-sparse CIM mode).
    Nonlinear work is activation-shaped and unchanged."""
    f = sparse_weight_factor(n, m, granularity, bits)
    t_dram = t_dram_weights(chip, bits) * f
    t_upd = GEOM.weight_bytes(bits) * f / CIM_WRITE_BW
    t_mac = t_mac_per_token(chip) * (n / m)
    t_nl = t_nl_per_token(fusion, ctx, chip)
    if rcw:
        return max(t_dram, t_upd) + t_mac + t_nl
    return t_dram + t_upd + t_mac + t_nl


def sparse_prefill_latency(n: int, m: int, granularity: str = "col",
                           tokens: int = 1024, rcw: bool = True,
                           chip: RCWCIMChip = RCWCIM,
                           bits: int = 4) -> float:
    """Prefill latency with N:M sparsity: MACs scale by n/m, the DRAM
    weight-stream term by the compression factor; the exposed-stall
    structure matches ``prefill_latency``."""
    f = sparse_weight_factor(n, m, granularity, bits)
    t_mac = t_mac_per_token(chip) * (n / m) * tokens / MAC_UTIL
    t_dram = prefill_dram_bytes(Dataflow.WS_OCS, tokens,
                                weight_scale=f) / (chip.dram_gbps * 1e9)
    if rcw:
        exposed = 0.0
    else:
        exposed = prefill_update_bytes(Dataflow.WS_OCS, tokens,
                                       weight_scale=f) / STALL_WRITE_BW
    return max(t_mac, t_dram) + exposed


def sparsity_report(n: int = 2, m: int = 4, granularity: str = "col",
                    bits: int = 4, ctx: int = 1024,
                    tokens: int = 1024) -> Dict[str, float]:
    """Dense vs N:M-sparse Dataflow rows (§14): weight footprint, prefill
    DRAM bytes, CIM weight-update bytes, and prefill/decode latency —
    each sparse number next to its dense WS-OCS baseline so the BENCH
    table shows what the compressed stream buys on top of Fig-8/Fig-9."""
    f = sparse_weight_factor(n, m, granularity, bits)
    d_wb = GEOM.weight_bytes(bits)
    d_dram = prefill_dram_bytes(Dataflow.WS_OCS, tokens)
    s_dram = prefill_dram_bytes(Dataflow.WS_OCS, tokens, weight_scale=f)
    d_upd = prefill_update_bytes(Dataflow.WS_OCS, tokens)
    s_upd = prefill_update_bytes(Dataflow.WS_OCS, tokens, weight_scale=f)
    d_dec = decode_latency(rcw=True, fusion=True, ctx=ctx)
    s_dec = sparse_decode_latency(n, m, granularity, ctx=ctx, bits=bits)
    d_pre = prefill_latency(Dataflow.WS_OCS, tokens)
    s_pre = sparse_prefill_latency(n, m, granularity, tokens, bits=bits)
    return {
        "n": n, "m": m, "granularity": granularity,
        "weight_factor": f,
        "dense_weight_mb": d_wb / 1e6,
        "sparse_weight_mb": d_wb * f / 1e6,
        "weight_reduction": 1 - f,
        "dense_prefill_dram_mb": d_dram / 1e6,
        "sparse_prefill_dram_mb": s_dram / 1e6,
        "dram_reduction": 1 - s_dram / d_dram,
        "dense_update_mb": d_upd / 1e6,
        "sparse_update_mb": s_upd / 1e6,
        "update_reduction": 1 - s_upd / d_upd,
        "dense_decode_ms": d_dec * 1e3,
        "sparse_decode_ms": s_dec * 1e3,
        "decode_speedup": d_dec / s_dec,
        "dense_prefill_s": d_pre,
        "sparse_prefill_s": s_pre,
        "prefill_speedup": d_pre / s_pre,
        "dense_tokens_per_s": 1 / d_dec,
        "sparse_tokens_per_s": 1 / s_dec,
    }


# ---------------------------------------------------------------------------
# Figure/Table reproductions
# ---------------------------------------------------------------------------

def fig8a_dram_reduction(tokens: int = 1024) -> Dict[str, float]:
    ws = prefill_dram_bytes(Dataflow.WS, tokens)
    ocs = prefill_dram_bytes(Dataflow.WS_OCS, tokens)
    return {"ws_bytes": ws, "ws_ocs_bytes": ocs,
            "reduction": 1 - ocs / ws, "paper": 0.516}


def fig8b_update_reduction(tokens: int = 1024) -> Dict[str, float]:
    os_upd = prefill_update_bytes(Dataflow.WS_OS, tokens)
    ocs = prefill_update_bytes(Dataflow.WS_OCS, tokens)
    return {"ws_os_updates": os_upd, "ws_ocs_updates": ocs,
            "reduction": 1 - ocs / os_upd, "paper": 0.876}


def fig9a_prefill_reduction(tokens: int = 1024) -> Dict[str, float]:
    base = prefill_latency(Dataflow.WS_OS, tokens, rcw=False)
    ocs = prefill_latency(Dataflow.WS_OCS, tokens, rcw=True)
    return {"baseline_s": base, "ws_ocs_s": ocs,
            "reduction": 1 - ocs / base, "paper": 0.4976,
            "per_token_ms": ocs / tokens * 1e3, "paper_per_token_ms": 4.2}


def fig9b_decode_reductions(ctx: int = 1024) -> Dict[str, float]:
    base = decode_latency(rcw=False, fusion=False, ctx=ctx)
    with_rcw = decode_latency(rcw=True, fusion=False, ctx=ctx)
    final = decode_latency(rcw=True, fusion=True, ctx=ctx)
    return {
        "baseline_ms": base * 1e3,
        "rcw_ms": with_rcw * 1e3,
        "final_ms": final * 1e3,
        "rcw_reduction": 1 - with_rcw / base, "paper_rcw": 0.2159,
        "fusion_reduction": 1 - final / with_rcw, "paper_fusion": 0.6917,
        "total_reduction": 1 - final / base, "paper_total": 0.7583,
        "tokens_per_s": 1 / final, "paper_tokens_per_s": 26.87,
    }


def table2_summary() -> Dict[str, float]:
    chip = RCWCIM
    final = decode_latency(rcw=True, fusion=True)
    power_w = chip.peak_tops / chip.tops_per_watt
    return {
        "throughput_tops": chip.peak_tops,
        "paper_tops": 3.28,
        "energy_eff_tops_per_w": chip.tops_per_watt,
        "paper_tops_per_w": 42.3,
        "power_w": power_w,
        "prefill_per_token_ms": prefill_per_token_ms(),
        "paper_prefill_ms": 4.2,
        "decode_tokens_per_s": 1 / final,
        "paper_decode_tokens_per_s": 26.87,
        "energy_per_token_mj": power_w * final * 1e3,
    }
