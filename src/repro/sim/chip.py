"""Hardware constants.

``RCWCIM`` — the paper's chip (TSMC 22 nm, 100 MHz, dual DDR5-6400): used
by the performance model that reproduces Table II / Fig 8 / Fig 9.

``TPU_V5E`` — the dry-run roofline target (197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI) per the task spec.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RCWCIMChip:
    # --- organization (paper Fig 2/3) ---
    clusters: int = 8
    cores_per_cluster: int = 4
    banks_per_core: int = 8
    macs_per_bank: int = 32
    freq_hz: float = 100e6
    # --- memory ---
    macro_kb: int = 256                 # per-core CIM macro (Table II)
    input_buf_kb: int = 64              # per-cluster input-reuse buffer
    psum_buf_kb: int = 64               # per-cluster partial-sum buffer
    dram_gbps: float = 2 * 51.2         # dual DDR5-6400 (51.2 GB/s each)
    # --- precisions ---
    weight_bits: int = 4
    act_bits: int = 8
    nl_bits: int = 16                   # FP16 nonlinear path
    # --- energy (fitted to Table II's 42.3 TOPS/W at INT4×INT8) ---
    tops_per_watt: float = 42.3

    @property
    def total_macs(self) -> int:
        return (self.clusters * self.cores_per_cluster
                * self.banks_per_core * self.macs_per_bank)

    @property
    def peak_tops(self) -> float:
        """Dual-INT4 mode: each INT8 MAC lane does 2 INT4 MACs/cycle.
        8×4×8×32 = 8192 MACs × 2 (dual int4) × 2 ops × 100 MHz
        = 3.28 TOPS — Table II."""
        return self.total_macs * 2 * 2 * self.freq_hz / 1e12

    @property
    def peak_ops_per_s(self) -> float:
        return self.peak_tops * 1e12

    @property
    def macro_total_bytes(self) -> int:
        """Total CIM weight capacity (32 macros × 256 KB)."""
        return (self.clusters * self.cores_per_cluster
                * self.macro_kb * 1024)


@dataclasses.dataclass(frozen=True)
class TPUChip:
    name: str = "v5e"
    peak_bf16_flops: float = 197e12
    hbm_bytes_per_s: float = 819e9
    ici_bytes_per_s_per_link: float = 50e9
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2    # ~128 MB VMEM on v5e


RCWCIM = RCWCIMChip()
TPU_V5E = TPUChip()
