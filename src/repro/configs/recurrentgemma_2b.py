"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, pattern (R,R,A) [arXiv:2402.19427].
head_dim=256 (10 heads × 256 = 2560); local window 2048."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26,
        d_model=2560, num_heads=10, num_kv_heads=1, d_ff=7680,
        vocab_size=256000, head_dim=256, rope_style="full", rope_theta=1e4,
        norm="rmsnorm", act="swiglu", block_pattern=("R", "R", "A"),
        window=2048, tie_embeddings=True, scan_layers=False,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=3, d_model=128, num_heads=4,
                          num_kv_heads=1, head_dim=32, d_ff=256,
                          vocab_size=512, window=32)


register("recurrentgemma-2b", full, smoke)
