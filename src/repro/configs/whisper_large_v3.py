"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend STUBBED per task spec
[arXiv:2212.04356]. 32 encoder + 32 decoder layers (whisper-large);
LayerNorm, GELU, learned decoder positions, tied embeddings; 1500
encoder frames. Decode shapes are lowered with the assigned 32k KV
geometry (shapes-only dry-run; see DESIGN.md §4)."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio", num_layers=32,
        d_model=1280, num_heads=20, num_kv_heads=20, d_ff=5120,
        vocab_size=51866, rope_style="none", norm="layernorm", act="gelu",
        qkv_bias=True, encoder_layers=32, encoder_seq=1500,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, encoder_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=4, d_ff=256,
                          vocab_size=512, encoder_seq=64)


register("whisper-large-v3", full, smoke)
