"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE (sections t=16, h=24, w=24 over head_dim/2=64),
dynamic-resolution vision frontend STUBBED per task spec
[arXiv:2409.12191; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", num_layers=28, d_model=1536,
        num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
        rope_style="mrope", rope_theta=1e6, norm="rmsnorm", act="swiglu",
        qkv_bias=True, mrope_sections=(16, 24, 24), vision_patches=256,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=256, vocab_size=512,
                          mrope_sections=(8, 4, 4), vision_patches=16)


register("qwen2-vl-2b", full, smoke)
