"""Model / run configuration system.

``ModelConfig`` is the single source of truth a model family module needs;
``ShapeSpec`` describes one assigned input-shape cell; ``registry`` maps
``--arch`` ids to config constructors. Every assigned architecture file in
this package instantiates the exact published dimensions and provides a
``smoke()`` reduction of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads
    rope_style: str = "full"          # full | half | mrope | none
    rope_theta: float = 1_000_000.0
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | gelu
    qkv_bias: bool = False
    parallel_block: bool = False      # command-r style attn ∥ mlp
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dense_ff: int = 0             # arctic's dense residual FFN width
    capacity_factor: float = 1.25
    moe_groups: int = 8               # GShard dispatch groups per batch
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 → ceil(d_model / 16)
    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("R", "R", "A")
    window: int = 0                   # local-attention window
    rglru_c: float = 8.0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500           # stub frontend frame count
    # --- VLM (qwen2-vl) ---
    mrope_sections: Tuple[int, ...] = ()
    vision_patches: int = 256         # stub frontend patch count
    # --- paper technique knobs ---
    quant_mode: str = "bf16"          # w4a8 | w8a8 | bf16 (serving uses w4a8)
    quant_group: int = 128
    softmax_group: int = 64
    norm_group: int = 128
    use_lut_softmax: bool = False
    use_fusion: bool = True           # group-norm/softmax fused ops on/off
    fuse_epilogue: bool = False       # fused-epilogue decode chain (§7):
                                      # norm→GEMM→act/GLU→residual in one
                                      # kernel dispatch per linear
    dataflow: str = "ws_ocs"          # kernel/scheduler dataflow selection
    rcw: bool = True                  # weight-stream overlap on/off
    sparsity: str = ""                # structured N:M weight sparsity
                                      # (§14): "" dense, "2:4" per-column,
                                      # "n:m:row" flexible per-row N-of-M;
                                      # consumed by quantize_params —
                                      # eligible weights are stored
                                      # compressed and routed through the
                                      # sparse WS-OCS kernels
    # --- numerics / compile ---
    dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: bool = True
    # --- sequence-parallel hint (long-context decode, batch=1) ---
    seq_shard_axis: Optional[str] = None

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:          # mamba
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim_

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str                         # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                         # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Archs whose every attention layer is full/global: a 500k dense-KV decode
# is architecturally quadratic → the long_500k cell is skipped for them
# (recorded in EXPERIMENTS.md). Sub-quadratic archs run it.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def long_500k_supported(cfg: ModelConfig) -> bool:
    return cfg.family in LONG_CONTEXT_FAMILIES


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (registers all archs)
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))
