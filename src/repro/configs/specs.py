"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns (step_kind, kwargs-of-structs) — the
exact abstract arguments the corresponding jitted step function is lowered
with. No device memory is ever allocated (the shannon/kernels pattern).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import api

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract cache pytree via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len))


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    specs = {"tokens": _sds((batch, seq), I32),
             "labels": _sds((batch, seq), I32)}
    if cfg.family == "audio":
        specs["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                               cfg.dtype)
    if cfg.family == "vlm":
        P = cfg.vision_patches
        specs["vision_embeds"] = _sds((batch, P, cfg.d_model), cfg.dtype)
        specs["positions"] = _sds((3, batch, seq + P), I32)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[str, Dict]:
    """→ (step_kind, kwargs) where step_kind ∈ train|prefill|decode and
    kwargs are the abstract args for that step (excluding params)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return "train", {"batch": train_batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), I32)}
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.dtype)
        cache_len = S
        if cfg.family == "vlm":
            P = cfg.vision_patches
            batch["vision_embeds"] = _sds((B, P, cfg.d_model), cfg.dtype)
            batch["positions"] = _sds((3, B, S + P), I32)
            cache_len = S + P      # merged vision+text sequence
        return "prefill", {"batch": batch,
                           "cache": cache_specs(cfg, B, cache_len)}
    # decode: one new token against a cache of seq_len
    return "decode", {
        "token": _sds((B, 1), I32),
        "cache": cache_specs(cfg, B, S),
        "pos_idx": _sds((), I32),
    }
