"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]. StarCoder2 uses
LayerNorm + GELU MLP + biases."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense", num_layers=32, d_model=4608,
        num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
        rope_style="full", rope_theta=1e5, norm="layernorm", act="gelu",
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=144, num_heads=6,
                          num_kv_heads=2, d_ff=288, vocab_size=512)


register("starcoder2-7b", full, smoke)
