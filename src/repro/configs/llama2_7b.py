"""llama2-7b — the paper's own evaluation model (Table II): 32L
d_model=4096 32H (MHA) d_ff=11008 vocab=32000; INT4 weights / INT8
activations / FP16 nonlinear in the serving configuration."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=32000,
        rope_style="full", rope_theta=1e4, norm="rmsnorm", act="swiglu",
    )


def serving() -> ModelConfig:
    """The paper's deployment config: W4A8 + LUT softmax + fusion."""
    return full().replace(quant_mode="w4a8", use_lut_softmax=True,
                          use_fusion=True, dataflow="ws_ocs", rcw=True)


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=4, d_ff=256, vocab_size=512)


register("llama2-7b", full, smoke)
