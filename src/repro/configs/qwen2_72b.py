"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
        rope_style="full", rope_theta=1e6, norm="rmsnorm", act="swiglu",
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=256, vocab_size=512)


register("qwen2-72b", full, smoke)
