"""Architecture configs: the 10 assigned archs + the paper's llama2-7b.
Importing this package registers every arch with the registry."""
from repro.configs import (  # noqa: F401
    arctic_480b, chatglm3_6b, command_r_35b, dbrx_132b, falcon_mamba_7b,
    llama2_7b, qwen2_72b, qwen2_vl_2b, recurrentgemma_2b, starcoder2_7b,
    whisper_large_v3,
)
from repro.configs.base import (  # noqa: F401
    SHAPES, ModelConfig, ShapeSpec, get_config, list_archs,
    long_500k_supported,
)
