"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16, mamba1 arch [arXiv:2410.05355].

The paper's group-softmax fusion is inapplicable (no softmax attention);
built without it — see DESIGN.md §Arch-applicability."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
        num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=65024,
        rope_style="none", norm="rmsnorm", ssm_state=16, d_conv=4,
        expand=2,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, vocab_size=512,
                          ssm_state=4)


register("falcon-mamba-7b", full, smoke)
