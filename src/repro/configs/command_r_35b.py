"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].
Cohere uses LayerNorm and a parallel attn∥mlp residual block."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", num_layers=40, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=22528, vocab_size=256000,
        rope_style="full", rope_theta=8e6, norm="layernorm", act="swiglu",
        qkv_bias=False, parallel_block=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=256, vocab_size=512)


register("command-r-35b", full, smoke)
