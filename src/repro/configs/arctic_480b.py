"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

Assumption (documented): the dense-residual FFN width is not given in the
assignment; we use d_ff (4864), matching the expert width — the
dense+MoE parallel-residual structure is what matters for the dataflow.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
        rope_style="full", rope_theta=1e6, norm="rmsnorm", act="swiglu",
        num_experts=128, num_experts_per_tok=2, moe_dense_ff=4864,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=64, vocab_size=512,
                          num_experts=8, num_experts_per_tok=2,
                          moe_dense_ff=64)


register("arctic-480b", full, smoke)
