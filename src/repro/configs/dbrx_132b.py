"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=10752, vocab_size=100352,
        rope_style="full", rope_theta=5e5, norm="layernorm", act="swiglu",
        num_experts=16, num_experts_per_tok=4,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=128, vocab_size=512,
                          num_experts=4, num_experts_per_tok=2)


register("dbrx-132b", full, smoke)
