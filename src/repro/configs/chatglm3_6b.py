"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (rotary on half of head_dim), GQA
[arXiv:2406.12793; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
        num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=65024,
        rope_style="half", rope_theta=1e4, norm="rmsnorm", act="swiglu",
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=256, vocab_size=512)


register("chatglm3-6b", full, smoke)
