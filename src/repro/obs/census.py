"""Dispatch accounting: one jaxpr-walking implementation for the whole
tree (DESIGN.md §15, unifying the PR 3/6/7 ad-hoc counters).

``serve/engine.py`` used to carry the jaxpr walk privately and expose it
three times over (``decode_eqn_count`` / ``prefill_eqn_count`` /
``verify_eqn_count``). The walk now lives here; the Engine methods are
thin shape-caching wrappers and any code can census an arbitrary jitted
callable with ``dispatch_census(fn, *args)``.

Counting semantics (unchanged from the original): descend into
control-flow bodies (scan / cond / pjit / remat — counted once, as
dispatch *shape*, not trip count) but treat a ``pallas_call`` as ONE
dispatch — its inner jaxpr is the kernel body, already fused on-chip.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax

# primitives broken out by every census unless told otherwise: total op
# dispatches, kernel launches, and the matmuls that escaped the kernel
# family (the DESIGN.md §11 kernel-residency metric)
DEFAULT_PRIMITIVES = (None, "pallas_call", "dot_general")


def _subjaxprs(v):
    vals = v if isinstance(v, (list, tuple)) else [v]
    for u in vals:
        if hasattr(u, "jaxpr"):          # ClosedJaxpr
            yield u.jaxpr
        elif hasattr(u, "eqns"):         # raw Jaxpr
            yield u


def count_eqns(jaxpr, primitive: Optional[str] = None) -> int:
    """Equations in a jaxpr, descending into control-flow bodies but
    treating a ``pallas_call`` as one dispatch. With ``primitive`` set,
    count only equations of that primitive (e.g. "pallas_call" → kernel
    launches)."""
    n = 0
    for eqn in jaxpr.eqns:
        if primitive is None or eqn.primitive.name == primitive:
            n += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                n += count_eqns(sub, primitive)
    return n


def census_jaxpr(jaxpr, primitives: Iterable[Optional[str]]
                 = DEFAULT_PRIMITIVES) -> Dict[str, int]:
    """Census an already-traced jaxpr (ClosedJaxpr or raw): primitive
    name → dispatch count, with key "total" for the all-primitives
    count (``primitive=None``)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    return {("total" if p is None else p): count_eqns(jaxpr, p)
            for p in primitives}


def dispatch_census(fn, *args,
                    primitives: Iterable[Optional[str]]
                    = DEFAULT_PRIMITIVES, **kwargs) -> Dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and census its dispatch schedule.

    The unified front door the ISSUE-10 satellite asks for: any jitted
    step — decode, prefill chunk, verify pass, or an arbitrary model
    function — yields a {primitive: count} dict through one call.
    Tracing is the expensive part (seconds for a scanned model); callers
    that census repeatedly at fixed shapes should trace once with
    ``jax.make_jaxpr`` and use ``census_jaxpr``, which is what
    ``Engine.*_eqn_count`` does via its per-shape caches."""
    return census_jaxpr(jax.make_jaxpr(fn)(*args, **kwargs), primitives)


def fold_census(metrics, census: Dict[str, int], phase: str) -> None:
    """Record a census into a Metrics registry as
    ``kernel_dispatches{phase=...,primitive=...}`` gauges — the
    scheduler folds one census per phase (decode / prefill / verify) at
    end of run so the Prometheus export carries the dispatch-shape
    counts next to the timing histograms."""
    for prim, n in census.items():
        metrics.gauge("kernel_dispatches",
                      {"phase": phase, "primitive": prim}).set(n)
