"""Low-overhead span/event tracer for the serving stack (DESIGN.md §15).

Zero-dependency request-lifecycle tracing: the paged scheduler (and the
disaggregated / speculative paths riding on it) records *spans*
(named intervals on the monotonic clock) and *instant events* into a
thread-safe ring buffer, exported on demand as Chrome ``trace_event``
JSON — loadable in ``chrome://tracing`` / Perfetto.

Design rules (the §15 overhead budget):

* **Disabled is free.** ``Tracer(enabled=False)`` — the default unless
  ``REPRO_TRACE=1`` — short-circuits every call after one attribute
  check; ``span()`` returns a shared ``nullcontext`` so no generator or
  frame is created. The serving hot loop may therefore call the tracer
  unconditionally.
* **Enabled is cheap.** A span costs two ``time.perf_counter()`` reads
  and one deque append; no string formatting, no I/O, no allocation
  beyond the record tuple. Export is the only expensive operation and
  happens outside the serving loop.
* **Bounded memory.** Records land in a ``deque(maxlen=capacity)`` —
  a long-running server overwrites its oldest spans instead of growing
  without bound. Lifecycle *root* spans (begin/end across many ticks)
  are tracked separately while open, so an open root is never evicted
  mid-request.

Span taxonomy (emitted by ``serve/paged/scheduler.py`` & co):

=================  ====  ===============================================
name               kind  meaning (tid)
=================  ====  ===============================================
request            root  admit → finish/preempt/handoff; one per
                         admission, so a preempted-then-replayed request
                         closes one root per attempt (tid = rid+1)
prefill_chunk      span  one chunked-prefill launch for one slot
                         (tid = rid+1)
decode_tick        span  one batched decode step, args n_active (tid 0)
verify_pass        span  one speculative draft+verify pass (tid 0)
first_token        evt   prompt complete, first token emitted — the
                         TTFT mark (tid = rid+1)
rollback           evt   speculative rejection: accepted < k drafts
                         (tid = rid+1)
preempt            evt   request evicted mid-decode (tid = rid+1)
finish             evt   request completed (tid = rid+1)
handoff / adopt    evt   disaggregated prefill→decode block transfer
                         (tid = rid+1)
=================  ====  ===============================================

tid 0 is the scheduler lane; request lanes are ``rid + 1`` so request 0
never collides with the scheduler. Chrome renders each tid as its own
track, so overlapping requests nest correctly without explicit
parent/child links.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional

SCHED_TID = 0

# one shared nullcontext for every disabled span() call — entering a
# nullcontext is reentrant-safe and stateless, so no per-call allocation
_NULL_CTX = contextlib.nullcontext()


def request_tid(rid: int) -> int:
    """Trace lane for request ``rid`` (lane 0 is the scheduler's)."""
    return rid + 1


class _SpanCM:
    """Tiny context manager recording one complete span on exit (no
    @contextmanager generator — ~3× cheaper to enter/exit)."""

    __slots__ = ("_tr", "_name", "_tid", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, tid: int, args):
        self._tr, self._name, self._tid, self._args = tr, name, tid, args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tr._record(self._name, self._tid, self._t0,
                         time.perf_counter(), self._args)
        return False


class Tracer:
    """Span/event recorder over a thread-safe ring buffer.

    Records are host-side tuples; jitted device work is *covered* by the
    spans that launch it (a span closes after the host-visible sync of
    its step), never instrumented inside. ``export_chrome`` serializes
    everything recorded so far without draining the buffer."""

    def __init__(self, enabled: bool = True, capacity: int = 1 << 16):
        self.enabled = enabled
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)   # (name,tid,t0,t1,args)
        self._events: deque = deque(maxlen=capacity)  # (name,tid,ts,args)
        self._open: Dict[int, tuple] = {}
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._t_epoch = time.perf_counter()

    # -- recording --------------------------------------------------------
    def _record(self, name, tid, t0, t1, args) -> None:
        self._buf.append((name, tid, t0, t1, args))

    def span(self, name: str, tid: int = SCHED_TID, **args):
        """Context manager timing one interval; free when disabled."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCM(self, name, tid, args or None)

    def event(self, name: str, tid: int = SCHED_TID, **args) -> None:
        """Instant event at now()."""
        if not self.enabled:
            return
        self._events.append((name, tid, time.perf_counter(), args or None))

    def begin(self, name: str, tid: int = SCHED_TID, **args) -> int:
        """Open a long-lived root span (closed by ``end``); returns a
        handle, or 0 when disabled (``end(0)`` is a no-op). Open roots
        live outside the ring buffer so they cannot be evicted."""
        if not self.enabled:
            return 0
        with self._lock:
            h = next(self._ids)
            self._open[h] = (name, tid, time.perf_counter(), dict(args))
        return h

    def end(self, handle: int, **args) -> None:
        if not handle:
            return
        with self._lock:
            name, tid, t0, a = self._open.pop(handle)
        if args:
            a.update(args)
        self._record(name, tid, t0, time.perf_counter(), a or None)

    # -- inspection -------------------------------------------------------
    @property
    def open_count(self) -> int:
        """Roots begun but not ended — 0 after a drained run (the
        no-orphan invariant tests assert)."""
        return len(self._open)

    def spans(self) -> List[Dict]:
        return [{"name": n, "tid": t, "t0": a, "t1": b,
                 "args": dict(g) if g else {}}
                for n, t, a, b, g in list(self._buf)]

    def events(self) -> List[Dict]:
        return [{"name": n, "tid": t, "ts": s,
                 "args": dict(g) if g else {}}
                for n, t, s, g in list(self._events)]

    def clear(self) -> None:
        """Drop all closed records (open roots survive — a mid-request
        clear must not orphan the request's eventual ``end``)."""
        with self._lock:
            self._buf.clear()
            self._events.clear()
            self._t_epoch = time.perf_counter()

    def span_seconds(self, name: str) -> float:
        """Total wall-clock covered by closed spans called ``name``."""
        return sum(b - a for n, _, a, b, _ in list(self._buf) if n == name)

    # -- export -----------------------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t_epoch) * 1e6

    def export_chrome(self, path=None, process_name: str = "repro-serve"
                      ) -> Dict:
        """Chrome ``trace_event`` JSON object format: complete ("X")
        events for spans, instant ("i") events, plus metadata rows
        naming the process and each tid lane. Returns the document;
        writes it to ``path`` when given."""
        ev: List[Dict] = [{
            "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
            "args": {"name": process_name}}]
        tids = set()
        for n, tid, t0, t1, args in list(self._buf):
            tids.add(tid)
            ev.append({"ph": "X", "pid": 0, "tid": tid, "name": n,
                       "cat": "serve", "ts": round(self._us(t0), 3),
                       "dur": round((t1 - t0) * 1e6, 3),
                       "args": dict(args) if args else {}})
        for n, tid, ts, args in list(self._events):
            tids.add(tid)
            ev.append({"ph": "i", "pid": 0, "tid": tid, "name": n,
                       "cat": "serve", "ts": round(self._us(ts), 3),
                       "s": "t", "args": dict(args) if args else {}})
        for tid in sorted(tids):
            ev.append({"ph": "M", "pid": 0, "tid": tid,
                       "name": "thread_name",
                       "args": {"name": "scheduler" if tid == SCHED_TID
                                else f"request {tid - 1}"}})
        doc = {"traceEvents": ev, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def validate_chrome_trace(doc: Dict) -> Dict:
    """Structural validation of an exported (or re-parsed) trace — the
    BENCH/test reconciliation helper. Checks the ``trace_event``
    contract: every record has a phase, X records carry ts+dur, and per
    tid the X spans are non-overlapping with monotone start times (one
    lane = one request's lifecycle = a clean span tree). Returns summary
    counts; raises ``ValueError`` on a malformed stream."""
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        raise ValueError("no traceEvents list")
    lanes: Dict[int, List] = {}
    n_spans = n_events = 0
    for e in evs:
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            raise ValueError(f"unknown phase {ph!r}")
        if ph == "M":
            continue
        if not isinstance(e.get("ts"), (int, float)):
            raise ValueError(f"event without ts: {e}")
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                raise ValueError(f"X event without dur: {e}")
            lanes.setdefault(e["tid"], []).append(
                (e["ts"], e["ts"] + e["dur"], e["name"]))
            n_spans += 1
        else:
            n_events += 1
    eps = 1e-3                                   # µs round-off slack
    for tid, spans in lanes.items():
        # records land in COMPLETION order (a root closes after its
        # children), so sort by start (ties: longest first) and check
        # proper nesting: within a lane a span either nests inside the
        # enclosing one or starts after it ends — partial overlap means
        # the stream is malformed (an orphan crossing lifecycle roots)
        spans.sort(key=lambda s: (s[0], s[0] - s[1]))
        stack: List = []
        for ts, te, name in spans:
            while stack and ts >= stack[-1][1] - eps:
                stack.pop()
            if stack and te > stack[-1][1] + eps:
                raise ValueError(
                    f"tid {tid}: span {name!r} [{ts}, {te}] partially "
                    f"overlaps enclosing {stack[-1][2]!r} "
                    f"[{stack[-1][0]}, {stack[-1][1]}]")
            stack.append((ts, te, name))
    return {"spans": n_spans, "events": n_events, "lanes": len(lanes)}


def request_lifecycles(doc: Dict) -> Dict[int, Dict]:
    """Group an exported trace by request lane: rid → {roots, children,
    events} where ``roots`` are the completed ``request`` spans (one per
    admission — preemption replays append another) and every child
    span/event is checked to fall inside some root's interval. Raises
    ``ValueError`` on an orphan (a child outside every root)."""
    out: Dict[int, Dict] = {}
    eps = 1e-3                                   # µs round-off slack
    for e in doc.get("traceEvents", []):
        tid = e.get("tid", 0)
        if e.get("ph") == "M" or tid == SCHED_TID:
            continue
        rid = tid - 1
        rec = out.setdefault(rid, {"roots": [], "children": [],
                                   "events": []})
        if e["ph"] == "X" and e["name"] == "request":
            rec["roots"].append(e)
        elif e["ph"] == "X":
            rec["children"].append(e)
        else:
            rec["events"].append(e)
    for rid, rec in out.items():
        if not rec["roots"]:
            raise ValueError(f"request {rid}: no completed root span")
        ivals = [(r["ts"] - eps, r["ts"] + r["dur"] + eps)
                 for r in rec["roots"]]
        for c in rec["children"] + rec["events"]:
            t0 = c["ts"]
            t1 = t0 + c.get("dur", 0.0)
            if not any(a <= t0 and t1 <= b for a, b in ivals):
                raise ValueError(
                    f"request {rid}: orphan {c['name']!r} at {t0} "
                    f"outside every root interval")
    return out
