"""Serving metrics: counters / gauges / histograms with a Prometheus
text exporter and a human summary table (DESIGN.md §15).

Same zero-cost-when-disabled contract as ``obs.trace``: a disabled
``Metrics`` registry hands out shared null instruments whose methods are
no-ops, so the scheduler hot loop calls ``metrics.counter(...)`` /
``.observe(...)`` unconditionally. Enabled instruments are plain Python
floats/lists behind a registry dict — no background threads, no
dependencies.

The serving stack populates (names as exported, ``repro_`` prefix added
at export time):

==============================  =========  ============================
metric                          kind       source
==============================  =========  ============================
tokens_emitted_total            counter    Scheduler._emit (exact match
                                           with returned sequences)
requests_admitted/finished/
preempted/replayed_total        counter    Scheduler lifecycle
decode_ticks_total              counter    Scheduler._decode_tick
prefill_chunks_total            counter    Scheduler._prefill_tick
verify_passes_total             counter    Scheduler._spec_tick
handoffs_total                  counter    DisaggScheduler
ttft_seconds                    histogram  admit → first token
inter_token_seconds             histogram  successive emits per request
decode_tick_seconds             histogram  one batched decode step
prefill_chunk_seconds           histogram  one chunked-prefill launch
verify_pass_seconds             histogram  one draft+verify pass
accepted_draft_length           histogram  tokens taken per verify pass
tick_active                     histogram  active slots per decode tick
prefix_cache_hit_rate           gauge      KVBlockPool (folded)
cow_copies/evictions/
preemptions_total               counter    KVBlockPool + Scheduler
pool_fragmentation              gauge      KVBlockPool (folded)
kernel_dispatches{phase=...}    gauge      obs.census fold-in
==============================  =========  ============================
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

# default histogram buckets (seconds) — spans µs-scale host work to
# multi-second prefills; counts-style histograms pass explicit buckets
_TIME_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0,
                 3.0, 10.0)


class _Null:
    """Shared do-nothing instrument for a disabled registry."""
    __slots__ = ()

    def inc(self, v: float = 1.0) -> None: ...
    def set(self, v: float) -> None: ...
    def observe(self, v: float) -> None: ...


_NULL = _Null()


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    """Fixed-bucket histogram with exact sum/count (Prometheus
    cumulative-bucket semantics at export)."""
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float] = _TIME_BUCKETS):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1 → +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _key(name: str, labels: Optional[Dict[str, str]]) -> str:
    if not labels:
        return name
    lab = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{lab}}}"


class Metrics:
    """Instrument registry. ``counter/gauge/histogram`` get-or-create by
    (name, labels); repeated calls return the same instrument, so call
    sites need no caching (though hot loops may keep a local ref)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._reg: Dict[str, object] = {}
        self._kind: Dict[str, str] = {}   # bare name → kind (for export)

    def _get(self, name: str, labels, kind, factory):
        if not self.enabled:
            return _NULL
        key = _key(name, labels)
        inst = self._reg.get(key)
        if inst is None:
            inst = self._reg[key] = factory()
            self._kind.setdefault(name, kind)
        return inst

    def counter(self, name: str, labels: Optional[Dict] = None) -> Counter:
        return self._get(name, labels, "counter", Counter)

    def gauge(self, name: str, labels: Optional[Dict] = None) -> Gauge:
        return self._get(name, labels, "gauge", Gauge)

    def histogram(self, name: str, labels: Optional[Dict] = None,
                  buckets: Sequence[float] = _TIME_BUCKETS) -> Histogram:
        return self._get(name, labels, "histogram",
                         lambda: Histogram(buckets))

    # -- reading ----------------------------------------------------------
    def get(self, name: str, labels: Optional[Dict] = None):
        """The live instrument, or None if never touched (useful in
        tests; never allocates)."""
        return self._reg.get(_key(name, labels))

    def value(self, name: str, labels: Optional[Dict] = None) -> float:
        inst = self.get(name, labels)
        if inst is None:
            return 0.0
        return inst.sum if isinstance(inst, Histogram) else inst.value

    def reset(self) -> None:
        self._reg.clear()
        self._kind.clear()

    # -- export -----------------------------------------------------------
    def export_prometheus(self, path=None, prefix: str = "repro_") -> str:
        """Prometheus text exposition format v0.0.4. Counters export as
        ``<prefix><name>`` (callers should already use ``_total``
        suffixes), histograms as cumulative ``_bucket{le=...}`` plus
        ``_sum``/``_count``."""
        by_name: Dict[str, List] = {}
        for key, inst in self._reg.items():
            name, brace, lab = key.partition("{")
            by_name.setdefault(name, []).append(
                (lab[:-1] if brace else "", inst))
        lines: List[str] = []
        for name in sorted(by_name):
            kind = self._kind.get(name, "gauge")
            full = prefix + name
            lines.append(f"# TYPE {full} {kind}")
            for lab, inst in sorted(by_name[name]):
                if isinstance(inst, Histogram):
                    cum = 0
                    for b, c in zip(inst.buckets, inst.counts):
                        cum += c
                        le = f'le="{b:g}"'
                        sep = "," if lab else ""
                        lines.append(
                            f"{full}_bucket{{{lab}{sep}{le}}} {cum}")
                    sep = "," if lab else ""
                    lines.append(
                        f'{full}_bucket{{{lab}{sep}le="+Inf"}} '
                        f"{inst.count}")
                    suffix = f"{{{lab}}}" if lab else ""
                    lines.append(f"{full}_sum{suffix} {inst.sum:g}")
                    lines.append(f"{full}_count{suffix} {inst.count}")
                else:
                    suffix = f"{{{lab}}}" if lab else ""
                    lines.append(f"{full}{suffix} {inst.value:g}")
        text = "\n".join(lines) + ("\n" if lines else "")
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def summary(self) -> str:
        """Human-readable table: one row per instrument, histograms as
        count/mean/max-bucket."""
        rows = []
        for key in sorted(self._reg):
            inst = self._reg[key]
            if isinstance(inst, Histogram):
                rows.append((key, f"n={inst.count} mean={inst.mean:.6g} "
                                  f"sum={inst.sum:.6g}"))
            else:
                rows.append((key, f"{inst.value:g}"))
        if not rows:
            return "(no metrics recorded)"
        w = max(len(k) for k, _ in rows)
        return "\n".join(f"{k:<{w}}  {v}" for k, v in rows)


def parse_prometheus(text: str) -> Dict[str, float]:
    """Inverse of ``export_prometheus`` for tests/benchmarks: sample
    name (with labels, without prefix handling) → value. ``# TYPE``
    lines are skipped; histogram series appear under their full
    ``_bucket``/``_sum``/``_count`` names."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, val = line.rpartition(" ")
        out[name] = float(val)
    return out
