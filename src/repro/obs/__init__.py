"""Serving telemetry (DESIGN.md §15): request-lifecycle tracing
(``obs.trace``), tok/s & latency metrics (``obs.metrics``), unified
dispatch accounting (``obs.census``), and the modeled-vs-measured drift
report (``obs.drift``).

Environment gates (flag table in ``parallel/flags.py``):

* ``REPRO_TRACE=1``   → the default Tracer records (else every call is
  a no-op after one attribute check)
* ``REPRO_METRICS=1`` → the default Metrics registry records (else all
  instruments are shared nulls)

The serving stack takes ``trace=``/``metrics=`` arguments everywhere;
``None`` means "use the env-gated defaults below". Tests and benchmarks
pass their own enabled instances so runs never share state through the
process-global singletons.
"""
from __future__ import annotations

import os

from repro.obs.census import (DEFAULT_PRIMITIVES, census_jaxpr, count_eqns,
                              dispatch_census, fold_census)
from repro.obs.drift import drift_report, format_report, \
    measured_weight_factor
from repro.obs.metrics import Counter, Gauge, Histogram, Metrics, \
    parse_prometheus
from repro.obs.trace import (SCHED_TID, Tracer, request_lifecycles,
                             request_tid, validate_chrome_trace)

__all__ = [
    "Tracer", "Metrics", "Counter", "Gauge", "Histogram",
    "SCHED_TID", "request_tid", "validate_chrome_trace",
    "request_lifecycles", "parse_prometheus",
    "count_eqns", "census_jaxpr", "dispatch_census", "fold_census",
    "DEFAULT_PRIMITIVES",
    "drift_report", "format_report", "measured_weight_factor",
    "default_tracer", "default_metrics",
]

_tracer = None
_metrics = None


def default_tracer() -> Tracer:
    """Process-wide tracer, enabled iff ``REPRO_TRACE=1`` at first use."""
    global _tracer
    if _tracer is None:
        _tracer = Tracer(enabled=os.environ.get("REPRO_TRACE") == "1")
    return _tracer


def default_metrics() -> Metrics:
    """Process-wide registry, enabled iff ``REPRO_METRICS=1`` at first
    use."""
    global _metrics
    if _metrics is None:
        _metrics = Metrics(enabled=os.environ.get("REPRO_METRICS") == "1")
    return _metrics
