"""Modeled-vs-measured drift report (DESIGN.md §15).

``sim/perf_model`` reproduces the paper's latency claims analytically
for the RCW-CIM chip; the serving stack runs on whatever host/TPU this
testbed has. Absolute times are therefore incomparable — what IS
comparable is the *shape* of the model: how decode cost scales with
occupancy, how prefill cost scales with tokens, what speculation and
sparsity multiply. The drift report checks exactly that:

* **Calibrated rows** (decode s/token, prefill s/token): a single scale
  κ — the geometric mean of measured/modeled over the calibrated rows —
  maps chip-modeled seconds onto testbed seconds. κ absorbs the
  platform gap; the per-row drift percentages are the *residuals* after
  calibration, so they are symmetric (decode +x% ⇔ prefill −x% for two
  rows) and sum to ~0 in log space. A small residual means the model's
  decode:prefill cost *ratio* matches the measured engine.
* **Dimensionless rows** need no calibration and compare directly:
  weight-stream amortization speedup (measured batched-vs-b1 tok/s
  ratio vs ``speedup_vs_b1``), tokens per verify pass (measured
  emitted/pass vs ``expected_tokens_per_pass`` at the realized
  acceptance), and the sparse weight-stream factor (measured compressed
  bytes on the wire vs ``sparse_weight_factor``).

The report consumes a populated ``obs.Metrics`` registry (the scheduler
fills ``decode_tick_seconds`` / ``prefill_chunk_seconds`` / ``tick_active``
/ ``accepted_draft_length`` as it runs) plus optional measured extras,
and prints/returns per-row drift percentages — the paper's Table-1/
Fig-8 claims checked continuously against the live engine.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.dataflow import Dataflow
from repro.sim import perf_model
from repro.obs.metrics import Histogram, Metrics


def measured_weight_factor(params) -> Optional[float]:
    """Realized weight-stream compression from a quantized pytree:
    (compressed value bytes + N:M metadata bytes) / dense value bytes,
    over every sparse leaf. None if the tree has no sparse leaves —
    mirrors ``perf_model.sparse_weight_factor`` from the measured side
    (scales excluded from both numerator and denominator: the dense
    baseline streams them too)."""
    sparse = dense = 0.0

    def walk(node):
        nonlocal sparse, dense
        if isinstance(node, dict):
            sp_keys = [k for k in node if k.startswith("sp") and "of" in k]
            if sp_keys and "q" in node:
                n, m = map(int, sp_keys[0][2:].split("of"))
                q, idx = node["q"], node[sp_keys[0]]
                sparse += q.nbytes + idx.nbytes
                dense += q.nbytes * m / n
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return (sparse / dense) if dense else None


def _mean(metrics: Metrics, name: str) -> float:
    h = metrics.get(name)
    return h.mean if isinstance(h, Histogram) else 0.0


def _row(name, measured, modeled, unit, calibrated):
    return {"name": name, "measured": measured, "modeled": modeled,
            "unit": unit, "calibrated": calibrated, "drift_pct": None}


def drift_report(metrics: Metrics, *, chunk: int = 32, ctx: int = 1024,
                 k: Optional[int] = None,
                 accept_rate: Optional[float] = None,
                 params=None,
                 b1_seconds_per_token: Optional[float] = None
                 ) -> List[Dict]:
    """Build the modeled-vs-measured rows from a populated registry.

    ``chunk``/``ctx`` describe the run (prefill chunk tokens, modeled
    context); ``k`` enables the tokens-per-pass row for a speculative
    run (realized acceptance defaults to the measured mean accepted
    length); ``params`` enables the sparse-factor row; and
    ``b1_seconds_per_token`` (a measured batch-1 arm) enables the
    amortization-speedup row. Rows with no measurement are skipped, so
    the report degrades gracefully on partial runs."""
    rows: List[Dict] = []

    ticks = metrics.get("tick_active")
    mean_active = ticks.mean if isinstance(ticks, Histogram) else 0.0
    dec_s = _mean(metrics, "decode_tick_seconds")
    if dec_s > 0 and mean_active >= 1:
        meas = dec_s / mean_active                # seconds per token
        modl = perf_model.amortized_decode_latency(mean_active, ctx=ctx)
        rows.append(_row("decode s/token (amortized)", meas, modl,
                         "s", True))

    pre_s = _mean(metrics, "prefill_chunk_seconds")
    if pre_s > 0:
        meas = pre_s / chunk
        modl = perf_model.prefill_latency(Dataflow.WS_OCS, chunk) / chunk
        rows.append(_row("prefill s/token (chunked)", meas, modl,
                         "s", True))

    if b1_seconds_per_token and dec_s > 0 and mean_active >= 1:
        meas = b1_seconds_per_token / (dec_s / mean_active)
        modl = perf_model.decode_latency(rcw=True, fusion=True, ctx=ctx) \
            / perf_model.amortized_decode_latency(mean_active, ctx=ctx)
        rows.append(_row("weight-stream amortization ×", meas, modl,
                         "x", False))

    if k:
        acc = metrics.get("accepted_draft_length")
        if isinstance(acc, Histogram) and acc.count:
            meas = acc.mean + 1.0            # emitted = accepted + bonus
            alpha = accept_rate if accept_rate is not None \
                else min(acc.mean / k, 1.0)
            modl = perf_model.expected_tokens_per_pass(k, alpha)
            rows.append(_row("tokens per verify pass", meas, modl,
                             "tok", False))

    if params is not None:
        meas = measured_weight_factor(params)
        if meas is not None:
            modl = perf_model.sparse_weight_factor(2, 4, "col", bits=4)
            rows.append(_row("sparse weight-stream factor", meas, modl,
                             "frac", False))

    # calibrate: κ = geometric mean of measured/modeled over the
    # seconds-valued rows, then drift = residual after scaling
    cal = [r for r in rows
           if r["calibrated"] and r["measured"] > 0 and r["modeled"] > 0]
    kappa = math.exp(sum(math.log(r["measured"] / r["modeled"])
                         for r in cal) / len(cal)) if cal else 1.0
    for r in rows:
        scale = kappa if r["calibrated"] else 1.0
        if r["modeled"]:
            r["drift_pct"] = (r["measured"] / (scale * r["modeled"])
                              - 1.0) * 100.0
        r["kappa"] = kappa if r["calibrated"] else None
    return rows


def format_report(rows: List[Dict]) -> str:
    """Human table: one modeled-vs-measured line per row with the drift
    percentage (post-calibration for seconds rows)."""
    if not rows:
        return "(no drift rows — run with metrics enabled)"
    kappa = next((r["kappa"] for r in rows if r.get("kappa")), None)
    head = "modeled-vs-measured drift"
    if kappa is not None:
        head += f" (platform scale kappa={kappa:.3g})"
    w = max(len(r["name"]) for r in rows)
    lines = [head]
    for r in rows:
        lines.append(
            f"  {r['name']:<{w}}  measured={r['measured']:.6g}"
            f" modeled={r['modeled']:.6g} {r['unit']:<4}"
            f" drift={r['drift_pct']:+.2f}%")
    return "\n".join(lines)
