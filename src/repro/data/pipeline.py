"""Deterministic synthetic LM data pipeline.

Production shape without external data: an infinite, *step-keyed* token
stream — batch contents are a pure function of (seed, step), so any
restart, any pod count, and any data-shard layout replays identically
(the fault-tolerance property the trainer's resume path relies on).

The generator synthesizes power-law-distributed token ids with local
n-gram structure (so losses actually decrease during the example runs)
plus packed document boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    mean_doc_len: int = 64
    bos: int = 1


class SyntheticLM:
    """Stateless batch oracle: ``batch_at(step)`` is pure."""

    def __init__(self, dc: DataConfig, cfg: Optional[ModelConfig] = None):
        self.dc = dc
        self.cfg = cfg
        # fixed "language" structure derived from the seed
        rng = np.random.default_rng(dc.seed)
        v = dc.vocab_size
        self._freq = (1.0 / np.arange(1, v + 1)) ** 1.1
        self._freq /= self._freq.sum()
        self._trans = rng.integers(0, v, size=(v, 4))  # 4 likely successors

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.default_rng((dc.seed, step))
        B, S, v = dc.batch_size, dc.seq_len, dc.vocab_size
        toks = np.empty((B, S), np.int32)
        base = rng.choice(v, size=(B, S), p=self._freq).astype(np.int32)
        follow = rng.random((B, S)) < 0.5
        pick = rng.integers(0, 4, size=(B, S))
        toks[:, 0] = dc.bos
        for t in range(1, S):
            nxt = self._trans[toks[:, t - 1], pick[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, base[:, t])
        # packed document boundaries
        doc_end = rng.random((B, S)) < (1.0 / dc.mean_doc_len)
        toks[doc_end] = dc.bos
        labels = np.roll(toks, -1, axis=1).astype(np.int32)
        labels[:, -1] = -100
        batch = {"tokens": toks, "labels": labels}
        if self.cfg is not None and self.cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(np.float32) * 0.02
        if self.cfg is not None and self.cfg.family == "vlm":
            P = self.cfg.vision_patches
            batch["vision_embeds"] = rng.standard_normal(
                (B, P, self.cfg.d_model)).astype(np.float32) * 0.02
            pos = np.broadcast_to(np.arange(S + P, dtype=np.int32)[None, None],
                                  (3, B, S + P)).copy()
            batch["positions"] = pos
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
