"""Dense decoder-only transformer (qwen2-72b, command-r-35b, chatglm3-6b,
starcoder2-7b, and the paper's llama2-7b).

Layers are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` (compact HLO, fast multi-pod compiles) with optional
full rematerialization. Supports sequential and parallel (command-r)
residual blocks, GQA, RoPE variants, and KV-cache prefill/decode.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _build_layer(mk: L.Maker, cfg: ModelConfig) -> Dict:
    p = {
        "ln1": L.make_norm(mk, cfg),
        "attn": L.make_attention(mk, cfg),
        "mlp": L.make_mlp(mk, cfg),
    }
    if not cfg.parallel_block:
        p["ln2"] = L.make_norm(mk, cfg)
    return p


def build(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "embed": L.make_embedding(mk, cfg),
        "layers": mk.stack(cfg.num_layers,
                           functools.partial(_build_layer, cfg=cfg)),
        "ln_f": L.make_norm(mk, cfg),
    }


def init(rng: jax.Array, cfg: ModelConfig) -> Dict:
    return build(L.InitMaker(rng, cfg.dtype), cfg)


def axes(cfg: ModelConfig) -> Dict:
    return build(L.AxesMaker(), cfg)


def _layer_fn(cfg: ModelConfig, x: jax.Array, pos: jax.Array, lp: Dict,
              cache: Optional[Dict], cache_index) -> Tuple[jax.Array, Optional[Dict]]:
    if L.fused_decode_applicable(lp, cfg, x, cache):
        # single-dispatch-per-op decode chain (DESIGN.md §7)
        return L.apply_decoder_layer_fused(lp, cfg, x, pos, cache,
                                           cache_index)
    h = L.apply_norm(lp["ln1"], x, cfg)
    attn_out, new_cache = L.apply_attention(
        lp["attn"], cfg, h, pos, causal=True, cache=cache,
        cache_index=cache_index)
    if cfg.parallel_block:
        mlp_out = L.apply_mlp(lp["mlp"], cfg, h)
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        x = x + L.apply_mlp(lp["mlp"], cfg, L.apply_norm(lp["ln2"], x, cfg))
    return x, new_cache


def _run_layers(params: Dict, cfg: ModelConfig, x: jax.Array,
                pos: jax.Array, cache: Optional[Dict], cache_index):
    """Scan the stacked layers; threads per-layer cache slices through."""

    from repro.parallel.act_sharding import constrain_residual

    def body(carry, xs):
        h = constrain_residual(carry)
        lp, lcache = xs
        out, new_cache = _layer_fn(cfg, h, pos, lp, lcache, cache_index)
        return constrain_residual(out), new_cache

    f = body
    if cfg.remat:
        f = jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(f, x, (params["layers"], cache))
    else:
        new_caches = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            lc = None if cache is None else jax.tree.map(lambda a: a[i], cache)
            x, nc = f(x, (lp, lc))
            new_caches.append(nc)
        new_cache = None if cache is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_caches)
    return x, new_cache


def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Teacher-forced logits (B, S, V) — the training forward."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _run_layers(params, cfg, x, pos, None, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    one = L.make_attn_cache(cfg, batch, max_len, dtype=cfg.dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, max_len: int) -> Dict:
    """Block-pool KV cache (DESIGN.md §10): per-layer shared pools
    (L, NB, BS, Hkv, D) plus per-request block tables (L, B, NBMAX) —
    the table is identical across layers (one logical table broadcast so
    the layer scan can thread it like any other cache leaf)."""
    one = L.make_paged_attn_cache(cfg, batch, num_blocks, block_size,
                                  max_len, dtype=cfg.dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            cache: Dict) -> Tuple[jax.Array, Dict]:
    """Run the prompt through the model, filling the cache from position 0.
    Returns (logits_last (B, V), cache)."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, cache = _run_layers(params, cfg, x, pos, cache, 0)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  cache: Dict, start: jax.Array) -> Tuple[jax.Array, Dict]:
    """Chunked prefill into a paged cache: tokens (B, C) occupy absolute
    positions start..start+C-1 (start (B,) int32); each chunk attends
    over the previously written prefix DIRECTLY through the block table
    (``ops.paged_flash_prefill`` — Pallas-resident on TPU, no dense
    prefix gather; DESIGN.md §11). Returns FULL-chunk logits (B, C, V)
    — the scheduler reads the row of the last real prompt token, so
    chunk padding needs no re-decode hack — and the updated cache."""
    B, C = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = start.reshape(B)[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    x, cache = _run_layers(params, cfg, x, pos, cache, start.reshape(B))
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg), cache


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array,
                cache: Dict, pos_idx: jax.Array) -> Tuple[jax.Array, Dict]:
    """One-token decode. token (B, 1) int32; pos_idx () int32 — the cache
    write position. Returns (logits (B, V), cache)."""
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    if hasattr(pos_idx, "ndim") and pos_idx.ndim == 1:   # per-slot (B,)
        pos = pos_idx[:, None]
    else:
        pos = jnp.broadcast_to(pos_idx[None, None], (B, 1))
    x, cache = _run_layers(params, cfg, x, pos, cache, pos_idx)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def cache_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": kv, "v": kv}


def paged_cache_axes(cfg: ModelConfig):
    """Logical axes of the paged layout (``init_paged_cache``): pools
    (L, NB, BS, Hkv, D), block table (L, B, NBMAX). Consumed by
    ``parallel.sharding.paged_cache_shardings`` (DESIGN.md §13)."""
    pool = ("layers", "blocks", "block_tokens", "kv_heads", "head_dim")
    return {"k": pool, "v": pool, "bt": ("layers", "batch", "table")}
