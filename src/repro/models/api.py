"""Uniform model API over the family modules.

Every family exposes: ``init(rng, cfg)``, ``axes(cfg)``, ``forward``,
``init_cache``, ``prefill``, ``decode_step``. This module adds the
batch-dict plumbing (family-specific extra inputs), the LM loss, and the
three canonical step functions the launcher/trainer/server jit:
``loss_fn``, ``prefill_step``, ``serve_step``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba, moe, recurrent, transformer, vlm, whisper

FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": mamba,
    "hybrid": recurrent,
    "vlm": vlm,
    "audio": whisper,
}

IGNORE = -100


def family(cfg: ModelConfig):
    return FAMILIES[cfg.family]


def init(rng: jax.Array, cfg: ModelConfig) -> Dict:
    return family(cfg).init(rng, cfg)


def axes(cfg: ModelConfig) -> Dict:
    return family(cfg).axes(cfg)


def forward(params: Dict, cfg: ModelConfig, batch: Dict) -> jax.Array:
    m = family(cfg)
    if cfg.family == "audio":
        return m.forward(params, cfg, batch["tokens"], batch["frames"])
    if cfg.family == "vlm":
        return m.forward(params, cfg, batch["tokens"],
                         batch.get("vision_embeds"),
                         batch.get("positions"))
    return m.forward(params, cfg, batch["tokens"])


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict) -> jax.Array:
    """Next-token cross entropy; labels == IGNORE are masked out."""
    logits = forward(params, cfg, batch)          # (B, S', V) f32
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:        # vlm: vision prefix
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=IGNORE)
    # shift: logits at t predict token t+1
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    mask = (targets != IGNORE).astype(jnp.float32)
    tgt = jnp.clip(targets, 0, cfg.vocab_size - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               num_blocks: Optional[int] = None, block_size: int = 16,
               mesh=None):
    """Dense (L, B, S, …) cache by default; with ``num_blocks`` set, the
    paged block-pool layout (pool + per-request block tables, DESIGN.md
    §10) for the attention families that support it. With ``mesh`` also
    set, the paged pools are placed with the §13 multi-device layout
    (kv_heads over "data", block ids global, tables replicated) via
    ``paged_cache_shardings``."""
    if num_blocks is not None:
        cache = family(cfg).init_paged_cache(cfg, batch, num_blocks,
                                             block_size, max_len)
        if mesh is not None:
            cache = jax.device_put(
                cache, paged_cache_shardings(cfg, cache, mesh))
        return cache
    assert mesh is None, "mesh placement is paged-only (DESIGN.md §13)"
    return family(cfg).init_cache(cfg, batch, max_len)


def paged_cache_axes(cfg: ModelConfig):
    """Logical axes tree mirroring init_paged_cache's structure."""
    return family(cfg).paged_cache_axes(cfg)


def paged_cache_shardings(cfg: ModelConfig, cache, mesh):
    """NamedSharding tree for a paged ``cache`` pytree ({"k","v"} pools
    plus optionally "bt") under the §13 paged serving rules. ``cache``
    leaves only need ``.shape``/``.dtype`` (arrays or ShapeDtypeStructs);
    extra leaves beyond k/v/bt are rejected by the axes-tree zip."""
    from repro.parallel import sharding as shd
    axes = paged_cache_axes(cfg)
    axes = {k: v for k, v in axes.items() if k in cache}
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), dict(cache))
    return shd.paged_cache_shardings(mesh, axes, shapes)


def prefill_step(params: Dict, cfg: ModelConfig, batch: Dict,
                 cache) -> Tuple[jax.Array, object]:
    m = family(cfg)
    if cfg.family == "audio":
        return m.prefill(params, cfg, batch["tokens"], cache,
                         batch["frames"])
    if cfg.family == "vlm":
        return m.prefill(params, cfg, batch["tokens"], cache,
                         batch.get("vision_embeds"), batch.get("positions"))
    return m.prefill(params, cfg, batch["tokens"], cache)


def prefill_chunk_step(params: Dict, cfg: ModelConfig, batch: Dict,
                       cache, start: jax.Array) -> Tuple[jax.Array, object]:
    """One chunked-prefill step into a paged cache: batch["tokens"]
    (B, C) written at absolute positions ``start`` (B,). Returns
    full-chunk logits (B, C, V) and the updated cache."""
    return family(cfg).prefill_chunk(params, cfg, batch["tokens"], cache,
                                     start)


def serve_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache,
               pos_idx: jax.Array) -> Tuple[jax.Array, object]:
    """One-token decode — the shape cells' ``decode_*`` / ``long_*`` step."""
    return family(cfg).decode_step(params, cfg, token, cache, pos_idx)


def verify_step(params: Dict, cfg: ModelConfig, tokens: jax.Array, cache,
                start: jax.Array) -> Tuple[jax.Array, object]:
    """Speculative-verify step (DESIGN.md §12): score a (B, K+1) batch of
    [pending token, K drafts] rows against the paged cache in ONE
    dispatch per op. Structurally this IS a chunked-prefill step — the
    chunk's K/V is written first, then the offset-causal
    ``ops.paged_flash_prefill`` attends over the written prefix — so
    speculative decode inverts the decode chain's one-token-per-dispatch
    assumption by reusing the prefill kernel path for decode. Row i of
    the returned (B, K+1, V) logits is the target's next-token
    distribution after tokens[:, :i+1]; greedy acceptance compares its
    argmax chain against the drafts (``spec_decode.accept_length``)."""
    return family(cfg).prefill_chunk(params, cfg, tokens, cache, start)


def topn_tokens(logits: jax.Array, n: int) -> jax.Array:
    """Deterministic n-best first tokens for beam forking: the n highest
    logits (ties broken toward the lower token id, ``jax.lax.top_k``
    order) — fork rank r continues from the r-th best token, so forked
    slots bit-match independently-seeded greedy runs."""
    _, idx = jax.lax.top_k(logits, n)
    return idx.astype(jnp.int32)


def cache_axes(cfg: ModelConfig):
    """Logical axes tree mirroring init_cache's structure."""
    return family(cfg).cache_axes(cfg)
