"""Whisper-large-v3-style encoder-decoder *backbone* (audio).

Per the task spec the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, frames, d_model). The encoder
is a bidirectional pre-LN transformer with sinusoidal positions; the
decoder has causal self-attention (KV cache), cross-attention over the
encoder output (K/V computed once at prefill and cached), learned
positions, and tied embeddings — all per the Whisper architecture.
Both stacks scan over stacked layers.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _sinusoid(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, jnp.float32) / dim)
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _build_enc_layer(mk, cfg):
    return {
        "ln1": L.make_norm(mk, cfg),
        "attn": L.make_attention(mk, cfg),
        "ln2": L.make_norm(mk, cfg),
        "mlp": L.make_mlp(mk, cfg),
    }


def _build_dec_layer(mk, cfg):
    return {
        "ln1": L.make_norm(mk, cfg),
        "self_attn": L.make_attention(mk, cfg),
        "ln2": L.make_norm(mk, cfg),
        "cross_attn": L.make_attention(mk, cfg, cross=True),
        "ln3": L.make_norm(mk, cfg),
        "mlp": L.make_mlp(mk, cfg),
    }


def build(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "embed": L.make_embedding(mk, cfg),
        "pos_dec": mk.param("pos_dec", (4096 * 16, cfg.d_model),
                            (None, "embed"), scale=0.02),
        "enc_layers": mk.stack(cfg.encoder_layers,
                               functools.partial(_build_enc_layer, cfg=cfg)),
        "ln_enc": L.make_norm(mk, cfg),
        "dec_layers": mk.stack(cfg.num_layers,
                               functools.partial(_build_dec_layer, cfg=cfg)),
        "ln_f": L.make_norm(mk, cfg),
    }


def init(rng, cfg):
    return build(L.InitMaker(rng, cfg.dtype), cfg)


def axes(cfg):
    return build(L.AxesMaker(), cfg)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params: Dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, F, d_model) — stub-frontend output — → (B, F, d_model)."""
    B, F, _ = frames.shape
    x = frames.astype(cfg.dtype) + _sinusoid(F, cfg.d_model).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    from repro.parallel.act_sharding import constrain_residual

    def body(carry, lp):
        carry = constrain_residual(carry)
        h = L.apply_norm(lp["ln1"], carry, cfg)
        attn, _ = L.apply_attention(lp["attn"], cfg, h, pos, causal=False,
                                    use_rope=False)
        x2 = carry + attn
        x2 = x2 + L.apply_mlp(lp["mlp"], cfg,
                              L.apply_norm(lp["ln2"], x2, cfg))
        return x2, None

    f = body
    if cfg.remat:
        f = jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(f, x, params["enc_layers"])
    else:
        for i in range(cfg.encoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
            x, _ = f(x, lp)
    return L.apply_norm(params["ln_enc"], x, cfg)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_layer(cfg, x, lp, enc_kv, self_cache, cache_index, pos):
    """enc_kv: dict {"k","v"} (B, F, H, D) — precomputed cross K/V."""
    B, S, _ = x.shape
    H, D = cfg.num_heads, cfg.head_dim_
    h = L.apply_norm(lp["ln1"], x, cfg)
    sa, new_cache = L.apply_attention(lp["self_attn"], cfg, h, pos,
                                      causal=True, cache=self_cache,
                                      cache_index=cache_index,
                                      use_rope=False)
    x = x + sa
    # cross-attention against cached encoder K/V
    h = L.apply_norm(lp["ln2"], x, cfg)
    q = L.apply_linear(lp["cross_attn"]["wq"], h, cfg).reshape(B, S, H, D)
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(enc_kv["k"], 1, 2)
    vh = jnp.swapaxes(enc_kv["v"], 1, 2)
    from repro.kernels import ops
    ca = ops.attention(qh, kh, vh, causal=False,
                       use_lut=cfg.use_lut_softmax)
    ca = jnp.swapaxes(ca, 1, 2).reshape(B, S, H * D).astype(x.dtype)
    x = x + L.apply_linear(lp["cross_attn"]["wo"], ca, cfg)
    x = x + L.apply_mlp(lp["mlp"], cfg, L.apply_norm(lp["ln3"], x, cfg))
    return x, new_cache


def cross_kv(params: Dict, cfg: ModelConfig, enc_out: jax.Array) -> Dict:
    """Precompute per-layer cross K/V (the decode-time cross cache)."""
    B, F, _ = enc_out.shape
    Hkv, D = cfg.num_kv_heads, cfg.head_dim_

    def one(lp):
        k = L.apply_linear(lp["cross_attn"]["wk"], enc_out, cfg)
        v = L.apply_linear(lp["cross_attn"]["wv"], enc_out, cfg)
        return {"k": k.reshape(B, F, Hkv, D), "v": v.reshape(B, F, Hkv, D)}

    return jax.vmap(one)(params["dec_layers"])


def _run_decoder(params, cfg, x, pos, enc_kv, cache, cache_index):
    from repro.parallel.act_sharding import constrain_residual

    def body(carry, xs):
        lp, ekv, lcache = xs
        out, nc = _dec_layer(cfg, constrain_residual(carry), lp, ekv,
                             lcache, cache_index, pos)
        return constrain_residual(out), nc

    f = body
    if cfg.remat:
        f = jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        return jax.lax.scan(f, x, (params["dec_layers"], enc_kv, cache))
    new_caches = []
    for i in range(cfg.num_layers):
        xs = jax.tree.map(lambda a: a[i],
                          (params["dec_layers"], enc_kv, cache))
        x, nc = f(x, xs)
        new_caches.append(nc)
    nc = None if cache is None else jax.tree.map(
        lambda *ys: jnp.stack(ys), *new_caches)
    return x, nc


def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            frames: jax.Array) -> jax.Array:
    """Teacher-forced decoder logits given stub-frontend frames."""
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    ekv = cross_kv(params, cfg, enc_out)
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    x = x + params["pos_dec"][:S].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _run_decoder(params, cfg, x, pos, ekv, None, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    one = L.make_attn_cache(cfg, batch, max_len, dtype=cfg.dtype)
    self_c = jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)
    F = cfg.encoder_seq
    kv = (cfg.num_layers, batch, F, cfg.num_kv_heads, cfg.head_dim_)
    return {"self": self_c,
            "cross": {"k": jnp.zeros(kv, cfg.dtype),
                      "v": jnp.zeros(kv, cfg.dtype)}}


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, cache: Dict,
            frames: jax.Array) -> Tuple[jax.Array, Dict]:
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    ekv = cross_kv(params, cfg, enc_out)
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    x = x + params["pos_dec"][:S].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, self_c = _run_decoder(params, cfg, x, pos, ekv, cache["self"], 0)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return (L.lm_logits(params["embed"], x[:, -1], cfg),
            {"self": self_c, "cross": ekv})


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array,
                cache: Dict, pos_idx: jax.Array) -> Tuple[jax.Array, Dict]:
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], pos_idx, 1, 0).astype(cfg.dtype)
    pos = jnp.broadcast_to(pos_idx[None, None], (B, 1))
    x, self_c = _run_decoder(params, cfg, x, pos, cache["cross"],
                             cache["self"], pos_idx)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return (L.lm_logits(params["embed"], x[:, -1], cfg),
            {"self": self_c, "cross": cache["cross"]})


def cache_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"self": {"k": kv, "v": kv}, "cross": {"k": kv, "v": kv}}
