"""Shared model-building blocks.

Parameters are plain pytrees (nested dicts of jnp arrays). Construction
goes through a ``Maker`` so the same builder code yields either real
initialized arrays (``InitMaker``) or logical sharding axes
(``AxesMaker``) — the two trees are structurally identical by
construction, which the sharding layer and tests rely on.

All normalization / softmax / quantized-matmul calls route through
``repro.kernels.ops`` so the paper's fused operators are first-class here.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.parallel import act_sharding


# ---------------------------------------------------------------------------
# Maker: one builder, two products (params | logical axes)
# ---------------------------------------------------------------------------

def is_axes_leaf(x) -> bool:
    """A logical-axes leaf is a tuple of axis names (str or None) — as
    opposed to structural tuples (e.g. heterogeneous layer stacks)."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


class Maker:
    def param(self, name, shape, axes, scale=None, dtype=None):
        raise NotImplementedError

    def stack(self, n: int, build: Callable[["Maker"], Dict]) -> Dict:
        raise NotImplementedError


class InitMaker(Maker):
    """Materializes initialized parameters."""

    def __init__(self, rng: jax.Array, dtype):
        self._rng = rng
        self.dtype = dtype

    def _next(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def param(self, name, shape, axes, scale=None, dtype=None):
        dtype = dtype or self.dtype
        if scale == 0.0:
            return jnp.zeros(shape, dtype)
        if scale == 1.0 and len(shape) == 1:
            return jnp.ones(shape, dtype)
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        std = scale if scale is not None else fan_in ** -0.5
        return (jax.random.normal(self._next(), shape, jnp.float32)
                * std).astype(dtype)

    def stack(self, n, build):
        def one(rng):
            return build(InitMaker(rng, self.dtype))
        rngs = jax.random.split(self._next(), n)
        return jax.vmap(one)(rngs)


class AxesMaker(Maker):
    """Produces the logical-axes tree (tuples of axis names, None = any)."""

    def __init__(self):
        self.dtype = None

    def param(self, name, shape, axes, scale=None, dtype=None):
        assert len(axes) == len(shape), (name, shape, axes)
        return tuple(axes)

    def stack(self, n, build):
        inner = build(AxesMaker())
        return jax.tree.map(lambda a: ("layers",) + a, inner,
                            is_leaf=is_axes_leaf)


# ---------------------------------------------------------------------------
# Norms (routed through the paper's fused group ops)
# ---------------------------------------------------------------------------

def make_norm(mk: Maker, cfg: ModelConfig, d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    p = {"gamma": mk.param("gamma", (d,), ("embed",), scale=1.0)}
    if cfg.norm == "layernorm":
        p["beta"] = mk.param("beta", (d,), ("embed",), scale=0.0)
    return p


def apply_norm(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    g = min(cfg.norm_group, x.shape[-1])
    if x.shape[-1] % g != 0:
        g = x.shape[-1]
    if cfg.norm == "layernorm":
        if cfg.use_fusion:
            return ops.group_layernorm(x, p["gamma"], p["beta"], group_size=g)
        mean = jnp.mean(x.astype(jnp.float32), -1, keepdims=True)
        var = jnp.var(x.astype(jnp.float32), -1, keepdims=True)
        return ((x - mean) * jax.lax.rsqrt(var + 1e-5) * p["gamma"]
                + p["beta"]).astype(x.dtype)
    if cfg.use_fusion:
        return ops.group_rmsnorm(x, p["gamma"], group_size=g)
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
    return (xf * inv * p["gamma"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE — full, half (chatglm 2d), M-RoPE (qwen2-vl)
# ---------------------------------------------------------------------------

def _rope_cos_sin(pos: jax.Array, dim: int, theta: float):
    """pos (..., S) → cos/sin (..., S, dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Pairwise (interleaved-half) rotation on the last dim."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_rope(x: jax.Array, pos: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B, S, H, D); pos (B, S) or (3, B, S) for M-RoPE."""
    d = x.shape[-1]
    if cfg.rope_style == "none":
        return x
    if cfg.rope_style == "half":
        # chatglm 2d-RoPE: rotate only the first half of head_dim
        dh = d // 2
        cos, sin = _rope_cos_sin(pos, dh, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        return jnp.concatenate(
            [_rotate(x[..., :dh], cos, sin), x[..., dh:]], -1)
    if cfg.rope_style == "mrope":
        # qwen2-vl M-RoPE: frequency bands split into (t, h, w) sections,
        # each section driven by its own position stream. pos (3, B, S).
        sections = cfg.mrope_sections or (d // 2,)
        assert sum(sections) == d // 2, (sections, d)
        cos_parts, sin_parts = [], []
        start = 0
        full_inv = 1.0 / (cfg.rope_theta
                          ** (jnp.arange(0, d, 2, jnp.float32) / d))
        for i, sec in enumerate(sections):
            inv = full_inv[start:start + sec]
            ang = pos[i].astype(jnp.float32)[..., None] * inv
            cos_parts.append(jnp.cos(ang))
            sin_parts.append(jnp.sin(ang))
            start += sec
        cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]
        sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
        return _rotate(x, cos, sin)
    cos, sin = _rope_cos_sin(pos, d, cfg.rope_theta)
    return _rotate(x, cos[:, :, None, :], sin[:, :, None, :])


# ---------------------------------------------------------------------------
# Linear (optionally quantized through the WS-OCS kernel)
# ---------------------------------------------------------------------------

def make_linear(mk: Maker, name: str, d_in: int, d_out: int,
                axes: Tuple[str, str], bias: bool = False) -> Dict:
    p = {"w": mk.param(f"{name}.w", (d_in, d_out), axes)}
    if bias:
        p["b"] = mk.param(f"{name}.b", (d_out,), (axes[1],), scale=0.0)
    return p


_SPARSE_KEY = re.compile(r"sp(\d+)of(\d+)$")


def sparse_meta(w: Dict) -> Optional[Tuple[str, int, int]]:
    """(key, n, m) when a quantized-weight dict carries N:M-compressed
    storage (a ``sp{n}of{m}`` metadata leaf, §14); None for dense. The
    ratio lives in the KEY so it stays static under vmap/scan; the
    granularity travels in the leaf's ndim (1 row / 2 col)."""
    for k in w:
        mm = _SPARSE_KEY.match(k)
        if mm:
            return k, int(mm.group(1)), int(mm.group(2))
    return None


def apply_linear(p: Dict, x: jax.Array, cfg: Optional[ModelConfig] = None) -> jax.Array:
    """x (..., d_in) @ w — through the quantized WS-OCS path when the
    config requests it and the weight is a serving-time QuantizedWeight
    (dict with 'q'/'scale'); N:M-compressed weights (extra sp{n}of{m}
    leaf) route through the sparse kernel family; plain dot otherwise
    (training)."""
    w = p["w"]
    if isinstance(w, dict):  # quantized serving weights (dtype carries bits)
        bits = 4 if w["q"].dtype == jnp.uint8 else 8
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        sp = sparse_meta(w)
        if sp is not None:
            key, sn, sm = sp
            out = ops.sparse_ws_ocs_matmul(
                x2, w["q"], w["scale"], w[key], n=sn, m=sm, bits=bits,
                rcw=bool(cfg.rcw) if cfg else True)
        else:
            out = ops.ws_ocs_matmul(x2, w["q"], w["scale"], bits=bits,
                                    rcw=bool(cfg.rcw) if cfg else True)
        out = out.reshape(lead + (out.shape[-1],)).astype(x.dtype)
    else:
        out = jnp.dot(x, w.astype(x.dtype))
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# GQA attention (+ KV cache)
# ---------------------------------------------------------------------------

def make_attention(mk: Maker, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": make_linear(mk, "wq", d, qd, ("embed", "qkv"), cfg.qkv_bias),
        "wk": make_linear(mk, "wk", d, kvd, ("embed", "kv"), cfg.qkv_bias),
        "wv": make_linear(mk, "wv", d, kvd, ("embed", "kv"), cfg.qkv_bias),
        "wo": make_linear(mk, "wo", qd, d, ("qkv", "embed"), False),
    }
    return p


def _split_heads(x: jax.Array, n: int, d: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, d))


def _per_slot(idx) -> bool:
    """A cache index is either a scalar () — whole-batch decode — or a
    per-sequence (B,) vector (continuous batching: each slot decodes at
    its own position)."""
    return hasattr(idx, "ndim") and idx.ndim == 1


def is_paged_cache(cache) -> bool:
    """A paged per-layer cache carries the block table alongside the
    pools: {"k": (NB, BS, Hkv, D), "v": ..., "bt": (B, NBMAX)}. The dense
    layout keeps {"k": (B, S, Hkv, D), "v": ...} (DESIGN.md §10)."""
    return isinstance(cache, dict) and "bt" in cache


def write_kv_cache_paged(cache: Dict, k: jax.Array, v: jax.Array,
                         start) -> Dict:
    """Scatter this step's K/V (B, S, Hkv, D) into the block pool at
    logical positions start..start+S-1 per request (start (B,) or scalar).
    Logical position p lives at pool block ``bt[b, p // BS]``, slot
    ``p % BS``. Unallocated table entries are 0 — the reserved null
    block — so inactive slots and chunk padding write harmlessly there
    (reads are masked by length / causality)."""
    pool_k, pool_v, bt = cache["k"], cache["v"], cache["bt"]
    NB, BS = pool_k.shape[0], pool_k.shape[1]
    B, S = k.shape[:2]
    if not _per_slot(start):
        start = jnp.full((B,), start, jnp.int32)
    p = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]   # (B, S)
    bidx = p // BS
    blk = jnp.take_along_axis(bt.astype(jnp.int32),
                              jnp.clip(bidx, 0, bt.shape[1] - 1), axis=1)
    # positions past the table (final-chunk padding crossing max_len) go
    # to the null block — NOT clipped onto the last live block, where the
    # duplicate-index scatter (last-wins) would overwrite real tokens
    blk = jnp.where(bidx >= bt.shape[1], 0, blk)
    flat = (blk * BS + p % BS).reshape(-1)
    tail = pool_k.shape[2:]
    new_k = pool_k.reshape((NB * BS,) + tail).at[flat].set(
        k.reshape((B * S,) + tail).astype(pool_k.dtype)).reshape(pool_k.shape)
    new_v = pool_v.reshape((NB * BS,) + tail).at[flat].set(
        v.reshape((B * S,) + tail).astype(pool_v.dtype)).reshape(pool_v.shape)
    return {"k": new_k, "v": new_v, "bt": bt}


def gather_paged_kv(cache: Dict) -> Tuple[jax.Array, jax.Array]:
    """Dense (B, NBMAX·BS, Hkv, D) K/V views assembled through the block
    table (chunked prefill reads the whole prefix this way; decode uses
    the gathering kernel instead)."""
    from repro.kernels.ref import gather_paged_kv_ref
    return (gather_paged_kv_ref(cache["k"], cache["bt"]),
            gather_paged_kv_ref(cache["v"], cache["bt"]))


def write_kv_cache(cache: Dict, k: jax.Array, v: jax.Array,
                   cache_index) -> Dict:
    """Write this step's K/V (B, S, Hkv, D) into the cache at
    ``cache_index`` (scalar or per-slot vector, see ``_per_slot``)."""
    idx = cache_index
    ck, cv = cache["k"], cache["v"]
    if _per_slot(idx):
        upd = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                c, u.astype(c.dtype), i, 0))
        return {"k": upd(ck, k, idx), "v": upd(cv, v, idx)}
    return {"k": jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), idx, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), idx, 1)}


def apply_attention(p: Dict, cfg: ModelConfig, x: jax.Array,
                    pos: jax.Array, *, causal: bool = True,
                    window: Optional[int] = None,
                    kv_x: Optional[jax.Array] = None,
                    cache: Optional[Dict] = None,
                    cache_index: Optional[jax.Array] = None,
                    use_rope: bool = True):
    """Returns (out, new_cache). Modes:
      * full forward (cache=None): self- or cross-attention over kv_x.
      phase with a cache: writes K/V at ``cache_index`` then attends over
      the cache prefix (decode: x is (B, 1, d)).
    """
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = _split_heads(apply_linear(p["wq"], x, cfg), H, D)
    src = kv_x if kv_x is not None else x
    k = _split_heads(apply_linear(p["wk"], src, cfg), Hkv, D)
    v = _split_heads(apply_linear(p["wv"], src, cfg), Hkv, D)
    if use_rope and cfg.rope_style != "none" and kv_x is None:
        q = apply_rope(q, pos, cfg)
        k = apply_rope(k, pos, cfg)

    new_cache = cache
    if cache is not None and kv_x is None and is_paged_cache(cache):
        # paged KV (DESIGN.md §10): positions map to pool blocks through
        # the per-request block table; cache_index is the (B,) start
        # position of this step's writes
        new_cache = write_kv_cache_paged(cache, k, v, cache_index)
        idx = cache_index if _per_slot(cache_index) \
            else jnp.full((B,), cache_index, jnp.int32)
        if S == 1:
            # decode: always the fused gathering dispatch (kernel on TPU,
            # gather + dense decode composition — bit-identical to the
            # dense unfused branch — elsewhere)
            out = ops.paged_attention_decode(
                q[:, 0], new_cache["k"], new_cache["v"], new_cache["bt"],
                idx + 1, group_size=cfg.softmax_group,
                use_lut=cfg.use_lut_softmax, window=window)
            out = out[:, :, None, :]             # (B, H, q=1, D)
        else:
            # chunked prefill: the chunk's queries (absolute positions
            # idx..idx+S-1) attend the written prefix straight through
            # the block table (DESIGN.md §11) — kernel on TPU, gather +
            # materialized oracle (the PR 5 path, bit-identical)
            # elsewhere; offset-causal masking bounds validity
            out = ops.paged_flash_prefill(
                jnp.swapaxes(q, 1, 2), new_cache["k"], new_cache["v"],
                new_cache["bt"], idx, window=window,
                use_lut=cfg.use_lut_softmax)
        # §13 multi-device serving: the pool is kv_head-sharded, so the
        # attention output arrives head-sharded — all-gather it before
        # the wo contraction to keep the reduction order (and therefore
        # the tokens) identical to the single-device engine
        out = act_sharding.constrain_replicated(out)
        out = jnp.swapaxes(out, 1, 2).astype(x.dtype)
    elif cache is not None and kv_x is None:
        new_cache = write_kv_cache(cache, k, v, cache_index)
        idx = cache_index
        per_slot = _per_slot(idx)
        k, v = new_cache["k"], new_cache["v"]
        Sk = k.shape[1]
        if S == 1 and cfg.fuse_epilogue and cfg.use_fusion:
            # fused single-dispatch decode: QK^T + group-softmax + PV in
            # one kernel on the cache layout (DESIGN.md §7) — no (B,H,S)
            # logits/probs tensors leave VMEM
            lengths = idx + S if per_slot \
                else jnp.full((B,), idx + S, jnp.int32)
            out = ops.attention_decode(
                q[:, 0], k, v, lengths,
                group_size=cfg.softmax_group, use_lut=cfg.use_lut_softmax,
                window=window)
            out = out[:, :, None, :]             # (B, H, q=1, D)
        elif S == 1:
            # decode: single query over the cache. Grouped-GQA einsums —
            # KV heads are NEVER repeated/transposed (a repeat forces
            # GSPMD to rematerialize a seq-sharded cache), and the cache
            # seq dim stays the last logits axis so a seq-over-"model"
            # cache (flash-decoding layout, REPRO_OPT_SEQKV=1) keeps all
            # score work shard-local with only tiny cross-shard reduces.
            G = H // Hkv
            qg = q[:, 0].reshape(B, Hkv, G, D)
            # mask out cache positions beyond idx + S (per-slot: vector)
            if per_slot:
                valid = jnp.arange(Sk)[None, :] < (idx[:, None] + S)
            else:
                valid = jnp.arange(Sk) < (idx + S)
            # cache stays bf16 (no f32 copies of S-length tensors); the
            # MXU-style f32 accumulation comes from preferred_element_type
            logits = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                                preferred_element_type=jnp.float32) \
                * (D ** -0.5)
            if valid.ndim == 2:          # per-slot validity (B, Sk)
                m = valid[:, None, None, :]
            else:
                m = valid[None, None, None, :]
            if window is not None:
                kpos = jnp.arange(Sk)[None, None, None, :]
                last = (idx[:, None, None, None] if per_slot else idx) \
                    + S - 1
                m = m & (kpos > (last - window))
            logits = jnp.where(m, logits, -1e30)
            if cfg.use_fusion:
                probs = ops.group_softmax(logits, cfg.softmax_group,
                                          use_lut=cfg.use_lut_softmax)
            else:
                probs = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v,
                             preferred_element_type=jnp.float32)
            out = out.reshape(B, H, 1, D)        # (B, H, q=1, D)
        else:
            # prefill into cache: attend causally over the written prefix
            # (prefill always starts at a static cache_index of 0)
            assert isinstance(idx, int) and idx == 0, "prefill needs idx=0"
            kq = jnp.swapaxes(q, 1, 2)
            kk = jnp.swapaxes(k, 1, 2)
            kv = jnp.swapaxes(v, 1, 2)
            out = ops.attention(kq, kk[:, :, :S], kv[:, :, :S],
                                causal=causal, window=window,
                                use_lut=cfg.use_lut_softmax)
        out = jnp.swapaxes(out, 1, 2).astype(x.dtype)
    else:
        kq = jnp.swapaxes(q, 1, 2)
        kk = jnp.swapaxes(k, 1, 2)
        kv = jnp.swapaxes(v, 1, 2)
        out = ops.attention(kq, kk, kv, causal=causal and kv_x is None,
                            window=window, use_lut=cfg.use_lut_softmax)
        out = jnp.swapaxes(out, 1, 2).astype(x.dtype)

    out = out.reshape(B, S, H * D)
    return apply_linear(p["wo"], out, cfg), new_cache


def make_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Dict:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def make_paged_attn_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                          block_size: int, max_len: int,
                          dtype=jnp.bfloat16) -> Dict:
    """One layer's paged cache: shared K/V pools of ``num_blocks`` blocks
    of ``block_size`` tokens (block 0 reserved as the null block) plus a
    per-request block table sized for max_len tokens."""
    assert max_len % block_size == 0, (max_len, block_size)
    nbmax = max_len // block_size
    shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "bt": jnp.zeros((batch, nbmax), jnp.int32)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def make_mlp(mk: Maker, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wg": make_linear(mk, "wg", d, f, ("embed", "mlp")),
            "wi": make_linear(mk, "wi", d, f, ("embed", "mlp")),
            "wo": make_linear(mk, "wo", f, d, ("mlp", "embed")),
        }
    return {
        "wi": make_linear(mk, "wi", d, f, ("embed", "mlp")),
        "wo": make_linear(mk, "wo", f, d, ("mlp", "embed")),
    }


def apply_mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if "wg" in p:
        h = jax.nn.silu(apply_linear(p["wg"], x, cfg)) \
            * apply_linear(p["wi"], x, cfg)
    else:
        h = jax.nn.gelu(apply_linear(p["wi"], x, cfg))
    return apply_linear(p["wo"], h, cfg)


# ---------------------------------------------------------------------------
# Fused-epilogue decode layer (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _quantized(p: Dict) -> bool:
    return isinstance(p.get("w"), dict)


def fused_decode_applicable(lp: Dict, cfg: ModelConfig, x: jax.Array,
                            cache: Optional[Dict]) -> bool:
    """The whole-layer fused chain handles the common dense decode case:
    S=1, RMSNorm pre-norm, every linear quantized for WS-OCS."""
    return (cfg.fuse_epilogue and cfg.use_fusion and cache is not None
            and x.shape[1] == 1 and cfg.norm == "rmsnorm"
            and all(_quantized(lp["attn"][k])
                    for k in ("wq", "wk", "wv", "wo"))
            and all(_quantized(v) for v in lp["mlp"].values()))


def _fused_linear(p: Dict, x2: jax.Array, **kw) -> jax.Array:
    w = p["w"]
    bits = 4 if w["q"].dtype == jnp.uint8 else 8
    sp = sparse_meta(w)
    if sp is not None:
        key, sn, sm = sp
        return ops.sparse_fused_matmul(x2, w["q"], w["scale"], w[key],
                                       n=sn, m=sm, bits=bits,
                                       bias=p.get("b"), **kw)
    # a GLU gate can only be sparse together with its main weight (they
    # share a shape, so sparsify eligibility is identical)
    assert "w2_idx" not in kw, "sparse GLU gate on a dense main weight"
    return ops.fused_matmul(x2, w["q"], w["scale"], bits=bits,
                            bias=p.get("b"), **kw)


def apply_decoder_layer_fused(lp: Dict, cfg: ModelConfig, x: jax.Array,
                              pos: jax.Array, cache: Dict, cache_index,
                              window: Optional[int] = None):
    """One decode step (B, 1, d) as a chain of fused kernels: each linear
    carries its pre-norm as a prologue and its add as an epilogue, the
    SwiGLU pair collapses to one dual-GEMM dispatch, and attention is the
    single-dispatch decode kernel — no S-length or d_ff-size fp32
    intermediate ever round-trips HBM (DESIGN.md §7). Only the tiny
    (B, H, D) rope rotation and the KV-cache write stay as jnp ops."""
    B, S, d = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    x2 = x.reshape(B, d)
    ng = min(cfg.norm_group, d)
    if d % ng != 0:
        ng = d
    g1 = lp["ln1"]["gamma"]

    q = _fused_linear(lp["attn"]["wq"], x2, gamma=g1, norm_group=ng)
    k = _fused_linear(lp["attn"]["wk"], x2, gamma=g1, norm_group=ng)
    v = _fused_linear(lp["attn"]["wv"], x2, gamma=g1, norm_group=ng)
    q = q.astype(x.dtype).reshape(B, 1, H, D)
    k = k.astype(x.dtype).reshape(B, 1, Hkv, D)
    v = v.astype(x.dtype).reshape(B, 1, Hkv, D)
    if cfg.rope_style != "none":
        q = apply_rope(q, pos, cfg)
        k = apply_rope(k, pos, cfg)
    idx = cache_index
    lengths = (idx + 1) if _per_slot(idx) \
        else jnp.full((B,), idx + 1, jnp.int32)
    if is_paged_cache(cache):
        new_cache = write_kv_cache_paged(cache, k, v, cache_index)
        attn = ops.paged_attention_decode(
            q[:, 0], new_cache["k"], new_cache["v"], new_cache["bt"],
            lengths, group_size=cfg.softmax_group,
            use_lut=cfg.use_lut_softmax, window=window)
        attn = act_sharding.constrain_replicated(attn)   # §13: pre-wo gather
    else:
        new_cache = write_kv_cache(cache, k, v, cache_index)
        attn = ops.attention_decode(
            q[:, 0], new_cache["k"], new_cache["v"], lengths,
            group_size=cfg.softmax_group, use_lut=cfg.use_lut_softmax,
            window=window)
    attn2 = attn.reshape(B, H * D).astype(x.dtype)
    x1 = _fused_linear(lp["attn"]["wo"], attn2,
                       residual=x2).astype(x.dtype)     # + residual, fused

    mp = lp["mlp"]
    if cfg.parallel_block:                    # attn ∥ mlp share ln1
        h_src, res, g2 = x2, x1, g1
    else:
        h_src, res, g2 = x1, x1, lp["ln2"]["gamma"]
    if "wg" in mp:
        # SwiGLU: gate GEMM + up GEMM + SiLU + product in one dispatch
        w2 = mp["wi"]["w"]
        kw2 = dict(w2_data=w2["q"], w2_scale=w2["scale"])
        sp2 = sparse_meta(w2)
        if sp2 is not None:               # wg/wi share a shape → same
            kw2["w2_idx"] = w2[sp2[0]]    # sparsify eligibility
        h = _fused_linear(mp["wg"], h_src, gamma=g2, norm_group=ng,
                          act="silu", **kw2)
    else:
        h = _fused_linear(mp["wi"], h_src, gamma=g2, norm_group=ng,
                          act="gelu")
    out = _fused_linear(mp["wo"], h.astype(x.dtype),
                        residual=res).astype(x.dtype)
    return out.reshape(B, 1, d), new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def make_embedding(mk: Maker, cfg: ModelConfig) -> Dict:
    p = {"table": mk.param("embed", (cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = mk.param("head", (cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"))
    return p


def embed_tokens(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def lm_logits(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["head"] if "head" in p else p["table"].T
    return jnp.dot(x, w.astype(x.dtype)).astype(jnp.float32)
