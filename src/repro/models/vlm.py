"""Qwen2-VL-style VLM *backbone*: a dense GQA transformer with M-RoPE.

Per the task spec, the vision frontend (ViT + dynamic-resolution patching)
is a STUB: ``input_specs`` provides precomputed patch embeddings
(B, P, d_model) which are prepended to the token embeddings, and 3-stream
(t, h, w) M-RoPE position ids cover the merged sequence. The transformer
stack is shared with :mod:`repro.models.transformer`.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

build = T.build
init = T.init
axes = T.axes
init_cache = T.init_cache
init_paged_cache = T.init_paged_cache
cache_axes = T.cache_axes
paged_cache_axes = T.paged_cache_axes


def merge_embeds(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                 vision_embeds: Optional[jax.Array]) -> jax.Array:
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cfg.dtype), x], axis=1)
    return x


def default_positions(cfg: ModelConfig, batch: int, seq: int,
                      start: int = 0) -> jax.Array:
    """(3, B, S) identical t/h/w streams — the text-only M-RoPE case.
    Real vision spans carry distinct h/w streams via input_specs."""
    p = jnp.broadcast_to(jnp.arange(start, start + seq)[None], (batch, seq))
    return jnp.broadcast_to(p[None], (3, batch, seq))


def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            vision_embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None) -> jax.Array:
    """Teacher-forced logits over the merged (vision + text) sequence."""
    B = tokens.shape[0]
    x = merge_embeds(params, cfg, tokens, vision_embeds)
    S = x.shape[1]
    pos = positions if positions is not None \
        else default_positions(cfg, B, S)
    x, _ = T._run_layers(params, cfg, x, pos, None, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, cache: Dict,
            vision_embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    B = tokens.shape[0]
    x = merge_embeds(params, cfg, tokens, vision_embeds)
    S = x.shape[1]
    pos = positions if positions is not None \
        else default_positions(cfg, B, S)
    x, cache = T._run_layers(params, cfg, x, pos, cache, 0)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  cache: Dict, start: jax.Array) -> Tuple[jax.Array, Dict]:
    """Chunked paged prefill, text-only (the stubbed vision prefix is a
    ROADMAP follow-on for paged serving): identical t/h/w M-RoPE streams
    starting at each request's absolute offset; attention goes
    block-table-direct through ``ops.paged_flash_prefill`` (§11)."""
    B, C = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    p = start.reshape(B)[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(p[None], (3, B, C))
    x, cache = T._run_layers(params, cfg, x, pos, cache, start.reshape(B))
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg), cache


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array,
                cache: Dict, pos_idx: jax.Array) -> Tuple[jax.Array, Dict]:
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    if hasattr(pos_idx, "ndim") and pos_idx.ndim == 1:   # per-slot (B,)
        pos = jnp.broadcast_to(pos_idx[None, :, None], (3, B, 1))
    else:
        pos = jnp.broadcast_to(pos_idx[None, None, None], (3, B, 1))
    x, cache = T._run_layers(params, cfg, x, pos, cache, pos_idx)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache
