"""Chunked linear-recurrence machinery shared by the SSM (mamba) and
RG-LRU (recurrentgemma) families.

The recurrence h_t = a_t ⊙ h_{t-1} + b_t is evaluated with a parallel
associative scan *within* fixed-size chunks and a sequential carry
*between* chunks: TPU-friendly (log-depth inside a chunk, O(S/chunk)
sequential steps) and memory-friendly (only chunk-sized (a, b) tensors are
alive; the chunk body is rematerialized under the layer checkpoint).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Analysis mode (set by launch/dryrun.py around the layer-extrapolation
# probes): forces single-chunk execution so XLA cost analysis — which
# counts a scan body only once — sees the full per-layer recurrence work.
FULL_CHUNK_ANALYSIS = False


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def linear_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array,
                      chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t ⊙ h_{t-1} + b_t along axis 1 (seq).

    a, b: (B, S, ...); h0: (B, ...). Returns (h (B,S,...), h_last).
    On TPU the elementwise (B, S, D) case routes through the fused
    VMEM-resident Pallas kernel (kernels/linear_recurrence.py)."""
    from repro.kernels import ops as _ops
    if a.ndim == 3 and _ops._use_pallas() and a.shape[1] >= 8:
        from repro.kernels.linear_recurrence import linear_recurrence_kernel
        bs = 128 if a.shape[1] % 128 == 0 else a.shape[1]
        bd = 256 if a.shape[2] % 256 == 0 else a.shape[2]
        return linear_recurrence_kernel(
            a, b, h0, block_s=bs, block_d=bd,
            interpret=_ops._interpret())
    B, S = a.shape[0], a.shape[1]
    if FULL_CHUNK_ANALYSIS:
        chunk = S
    chunk = min(chunk, S)
    if S % chunk != 0:  # fall back to one associative scan over the rest
        chunk = S
    n_chunks = S // chunk

    ac = a.reshape((B, n_chunks, chunk) + a.shape[2:])
    bc = b.reshape((B, n_chunks, chunk) + b.shape[2:])

    def chunk_body(h_prev, xs):
        a_k, b_k = xs                     # (B, chunk, ...)
        # fold the carry into the first step: b'_0 = a_0 h_prev + b_0
        b_k = b_k.at[:, 0].add(a_k[:, 0] * h_prev)
        A, Bv = jax.lax.associative_scan(_combine, (a_k, b_k), axis=1)
        return Bv[:, -1], Bv

    h_last, hs = jax.lax.scan(chunk_body, h0,
                              (jnp.moveaxis(ac, 1, 0), jnp.moveaxis(bc, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S) + a.shape[2:])
    return hs, h_last


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array = None):
    """Depthwise causal conv along seq. x (B, S, C); w (K, C);
    state (B, K-1, C) carries the tail of the previous segment.
    Returns (y (B, S, C), new_state (B, K-1, C))."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros((B, S, C), x.dtype)
    for i in range(K):  # K is 4 — unrolled taps beat a conv op here
        y = y + xp[:, i : i + S] * w[i]
    new_state = xp[:, S:]
    return y, new_state
