"""RecurrentGemma-style (Griffin) hybrid: RG-LRU recurrent blocks + local
sliding-window attention in a repeating (R, R, A) pattern.

Sub-quadratic by construction (bounded window + O(1) recurrent state), so
this family runs the long_500k cell. The RG-LRU recurrence is elementwise
(no softmax) — the paper's softmax fusion applies only to the local-
attention layers (DESIGN.md §Arch-applicability); group-RMSNorm and
WS-OCS GEMMs apply everywhere. Layers are heterogeneous, so the stack is
an unrolled loop over per-layer param dicts (26 small layers — compile
cost is acceptable; see DESIGN.md §9).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.scan_utils import causal_conv1d, linear_recurrence


def layer_kinds(cfg: ModelConfig) -> List[str]:
    pat = cfg.block_pattern or ("R", "R", "A")
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _build_rec_layer(mk: L.Maker, cfg: ModelConfig) -> Dict:
    d, w = cfg.d_model, cfg.d_model  # lru_width = d_model
    return {
        "ln1": L.make_norm(mk, cfg),
        "wy": L.make_linear(mk, "wy", d, w, ("embed", "inner")),
        "wx": L.make_linear(mk, "wx", d, w, ("embed", "inner")),
        "conv_w": mk.param("conv_w", (cfg.d_conv, w), (None, "inner"),
                           scale=cfg.d_conv ** -0.5),
        "wa": L.make_linear(mk, "wa", w, w, ("inner", "inner"), bias=True),
        "wi": L.make_linear(mk, "wi", w, w, ("inner", "inner"), bias=True),
        "lam": mk.param("lam", (w,), ("inner",), scale=1.0),
        "wo": L.make_linear(mk, "wo", w, d, ("inner", "embed")),
        "ln2": L.make_norm(mk, cfg),
        "mlp": L.make_mlp(mk, cfg),
    }


def _build_attn_layer(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.make_norm(mk, cfg),
        "attn": L.make_attention(mk, cfg),
        "ln2": L.make_norm(mk, cfg),
        "mlp": L.make_mlp(mk, cfg),
    }


def build(mk: L.Maker, cfg: ModelConfig) -> Dict:
    layers = []
    for kind in layer_kinds(cfg):
        builder = _build_rec_layer if kind == "R" else _build_attn_layer
        layers.append(builder(mk, cfg))
    return {
        "embed": L.make_embedding(mk, cfg),
        "layers": tuple(layers),
        "ln_f": L.make_norm(mk, cfg),
    }


def init(rng, cfg):
    return build(L.InitMaker(rng, cfg.dtype), cfg)


def axes(cfg):
    ax = build(L.AxesMaker(), cfg)
    # "kind" markers are static strings, not params — strip from axes too
    return ax


def _rglru(lp: Dict, cfg: ModelConfig, x: jax.Array,
           h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """RG-LRU: a_t = exp(−c·softplus(Λ)·r_t); h_t = a_t h_{t−1} +
    √(1−a_t²)·(i_t ⊙ x_t). Elementwise — runs via the shared chunked
    associative scan."""
    r = jax.nn.sigmoid(L.apply_linear(lp["wa"], x, cfg).astype(jnp.float32))
    i = jax.nn.sigmoid(L.apply_linear(lp["wi"], x, cfg).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(lp["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * gated
    hs, h_last = linear_recurrence(a, b, h0)
    return hs.astype(x.dtype), h_last


def _rec_block(lp, cfg, x, state):
    """Temporal-mix for an R layer. state {"h": (B,w) f32, "conv": ...}."""
    B = x.shape[0]
    w = cfg.d_model
    y = jax.nn.gelu(L.apply_linear(lp["wy"], x, cfg))
    xb = L.apply_linear(lp["wx"], x, cfg)
    conv0 = None if state is None else state["conv"].astype(xb.dtype)
    xb, new_conv = causal_conv1d(xb, lp["conv_w"].astype(xb.dtype), conv0)
    h0 = jnp.zeros((B, w), jnp.float32) if state is None else state["h"]
    hs, h_last = _rglru(lp, cfg, xb, h0)
    out = L.apply_linear(lp["wo"], hs * y, cfg)
    new_state = None if state is None else {"h": h_last, "conv": new_conv}
    return out, new_state


def _ring_write(cache_kv: jax.Array, new: jax.Array, pos: jax.Array):
    """Write a single-step K/V (B, 1, H, D) into the (B, W, H, D) ring
    buffer at slot pos % W."""
    W = cache_kv.shape[1]
    return jax.lax.dynamic_update_slice_in_dim(
        cache_kv, new.astype(cache_kv.dtype), pos % W, 1)


def _attn_block(lp, cfg, x, pos, state, pos_idx):
    """Temporal-mix for an A layer (local window attention).

    Full-sequence mode (state None or prefill): windowed flash attention.
    Decode mode (S==1): ring-buffer KV cache of size window — O(1) memory
    for arbitrarily long sequences (the long_500k path)."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    Wn = cfg.window
    if state is None or S > 1:
        h = x
        q = L.apply_linear(lp["attn"]["wq"], h, cfg).reshape(B, S, H, D)
        k = L.apply_linear(lp["attn"]["wk"], h, cfg).reshape(B, S, Hkv, D)
        v = L.apply_linear(lp["attn"]["wv"], h, cfg).reshape(B, S, Hkv, D)
        q = L.apply_rope(q, pos, cfg)
        k = L.apply_rope(k, pos, cfg)
        out = ops_attention(q, k, v, cfg, window=Wn)
        out = L.apply_linear(lp["attn"]["wo"], out.reshape(B, S, H * D), cfg)
        new_state = None
        if state is not None:  # prefill: stash the last `window` keys
            kc, vc = state["k"], state["v"]
            Wc = kc.shape[1]
            take = min(Wc, S)
            # ring layout: token t lives at slot t % W
            src_pos = jnp.arange(take) + (S - take)
            slots = src_pos % Wc
            kc = kc.at[:, slots].set(k[:, S - take:].astype(kc.dtype))
            vc = vc.at[:, slots].set(v[:, S - take:].astype(vc.dtype))
            new_state = {"k": kc, "v": vc}
        return out, new_state
    # ---- decode ----
    h = x
    q = L.apply_linear(lp["attn"]["wq"], h, cfg).reshape(B, 1, H, D)
    k = L.apply_linear(lp["attn"]["wk"], h, cfg).reshape(B, 1, Hkv, D)
    v = L.apply_linear(lp["attn"]["wv"], h, cfg).reshape(B, 1, Hkv, D)
    q = L.apply_rope(q, pos, cfg)
    k = L.apply_rope(k, pos, cfg)
    kc = _ring_write(state["k"], k, pos_idx)
    vc = _ring_write(state["v"], v, pos_idx)
    Wc = kc.shape[1]
    valid = jnp.arange(Wc)[None, None, None, :] <= pos_idx  # slots filled
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.repeat(jnp.swapaxes(kc, 1, 2), H // Hkv, axis=1)
    vh = jnp.repeat(jnp.swapaxes(vc, 1, 2), H // Hkv, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qh.astype(jnp.float32),
                        kh.astype(jnp.float32)) * (D ** -0.5)
    logits = jnp.where(valid, logits, -1e30)
    if cfg.use_fusion:
        from repro.kernels import ops
        probs = ops.group_softmax(logits, cfg.softmax_group,
                                  use_lut=cfg.use_lut_softmax)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(jnp.float32),
                     vh.astype(jnp.float32))
    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, H * D).astype(x.dtype)
    out = L.apply_linear(lp["attn"]["wo"], out, cfg)
    return out, {"k": kc, "v": vc}


def ops_attention(q, k, v, cfg, window):
    from repro.kernels import ops
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = ops.attention(qh, kh, vh, causal=True, window=window,
                        use_lut=cfg.use_lut_softmax)
    return jnp.swapaxes(out, 1, 2)


def _layer(kind, cfg, lp, x, pos, state, pos_idx):
    h = L.apply_norm(lp["ln1"], x, cfg)
    if kind == "R":
        mix, new_state = _rec_block(lp, cfg, h, state)
    else:
        mix, new_state = _attn_block(lp, cfg, h, pos, state, pos_idx)
    x = x + mix
    x = x + L.apply_mlp(lp["mlp"], cfg, L.apply_norm(lp["ln2"], x, cfg))
    return x, new_state


def _run(params, cfg, x, pos, states, pos_idx):
    kinds = layer_kinds(cfg)
    new_states = []
    for i, kind in enumerate(kinds):
        lp = params["layers"][i]
        st = None if states is None else states[i]

        def fn(lp_, x_, pos_, st_, pidx_, _kind=kind):
            return _layer(_kind, cfg, lp_, x_, pos_, st_, pidx_)

        if cfg.remat and states is None:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        from repro.parallel.act_sharding import constrain_residual
        x = constrain_residual(x)
        x, ns = fn(lp, x, pos, st, pos_idx)
        new_states.append(ns)
    return x, (None if states is None else tuple(new_states))


def forward(params, cfg, tokens):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _run(params, cfg, x, pos, None, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    del max_len  # bounded: ring window for A layers, O(1) state for R
    w = cfg.d_model
    states = []
    for kind in layer_kinds(cfg):
        if kind == "R":
            states.append({
                "h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.d_conv - 1, w), cfg.dtype),
            })
        else:
            kv = (batch, cfg.window, cfg.num_kv_heads, cfg.head_dim_)
            states.append({"k": jnp.zeros(kv, cfg.dtype),
                           "v": jnp.zeros(kv, cfg.dtype)})
    return tuple(states)


def prefill(params, cfg, tokens, cache):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, cache = _run(params, cfg, x, pos, cache, 0)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def decode_step(params, cfg, token, cache, pos_idx):
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    pos = jnp.broadcast_to(pos_idx[None, None], (B, 1))
    x, cache = _run(params, cfg, x, pos, cache, pos_idx)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def cache_axes(cfg: ModelConfig):
    axes = []
    for kind in layer_kinds(cfg):
        if kind == "R":
            axes.append({"h": ("batch", "inner"),
                         "conv": ("batch", None, "inner")})
        else:
            kv = ("batch", "seq", "kv_heads", "head_dim")
            axes.append({"k": kv, "v": kv})
    return tuple(axes)
