"""Model zoo: one module per architecture family, unified by api.py."""
from repro.models import api  # noqa: F401
