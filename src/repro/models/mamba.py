"""Mamba-1 selective SSM (falcon-mamba-7b): 64 attention-free layers.

The paper's group-softmax fusion is inapplicable here (no softmax
attention — DESIGN.md §Arch-applicability); WS-OCS quantized GEMMs apply
to the in/x/dt/out projections, and group-RMSNorm applies as usual. The
selective scan runs as a chunked associative scan (scan_utils) — the
TPU-idiomatic form of the recurrence.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.scan_utils import causal_conv1d


def _build_layer(mk: L.Maker, cfg: ModelConfig) -> Dict:
    d, di, st, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    return {
        "ln": L.make_norm(mk, cfg),
        "in_proj": L.make_linear(mk, "in_proj", d, 2 * di, ("embed", "inner")),
        "conv_w": mk.param("conv_w", (cfg.d_conv, di), (None, "inner"),
                           scale=cfg.d_conv ** -0.5),
        "conv_b": mk.param("conv_b", (di,), ("inner",), scale=0.0),
        "x_proj": L.make_linear(mk, "x_proj", di, dr + 2 * st,
                                ("inner", None)),
        "dt_proj": L.make_linear(mk, "dt_proj", dr, di, (None, "inner"),
                                 bias=True),
        "A_log": mk.param("A_log", (di, st), ("inner", "state"), scale=1.0),
        "D": mk.param("D", (di,), ("inner",), scale=1.0),
        "out_proj": L.make_linear(mk, "out_proj", di, d, ("inner", "embed")),
    }


def build(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "embed": L.make_embedding(mk, cfg),
        "layers": mk.stack(cfg.num_layers,
                           functools.partial(_build_layer, cfg=cfg)),
        "ln_f": L.make_norm(mk, cfg),
    }


def init(rng, cfg):
    return build(L.InitMaker(rng, cfg.dtype), cfg)


def axes(cfg):
    return build(L.AxesMaker(), cfg)


_CHUNK = 256  # seq chunk: bounds the live (B, chunk, di, state) tensors


def _mixer_chunk(lp: Dict, cfg: ModelConfig, xc: jax.Array,
                 h0: jax.Array, conv_state: jax.Array):
    """One sequence chunk through the full mixer. xc (B, ck, d)."""
    B = xc.shape[0]
    di, st, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    xz = L.apply_linear(lp["in_proj"], xc, cfg)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_conv = causal_conv1d(xs, lp["conv_w"].astype(xs.dtype), conv_state)
    xs = jax.nn.silu(xs + lp["conv_b"].astype(xs.dtype))

    proj = L.apply_linear(lp["x_proj"], xs, cfg)
    dt, Bmat, Cmat = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(L.apply_linear(lp["dt_proj"], dt, cfg))

    # fused VMEM-resident scan (Pallas on TPU; jnp oracle elsewhere) —
    # the hardware-aware form: no (B,S,di,st) HBM tensors
    from repro.kernels import ops
    y, h_last = ops.selective_scan(
        dt.astype(jnp.float32), xs.astype(jnp.float32),
        Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
        lp["A_log"], h0)
    y = y + xs.astype(jnp.float32) * lp["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype)
    return L.apply_linear(lp["out_proj"], y, cfg), h_last, new_conv


def _mixer(lp: Dict, cfg: ModelConfig, x: jax.Array,
           state: Optional[Dict]) -> Tuple[jax.Array, Optional[Dict]]:
    """x (B, S, d) → (B, S, d); state {"h": (B,di,st) f32, "conv":
    (B,K-1,di)} threads decode/prefill recurrent state. The sequence is
    processed in _CHUNK-sized pieces so only chunk-sized (B,ck,di,st)
    tensors are ever alive (DESIGN.md: the SSM memory discipline)."""
    B, S, _ = x.shape
    di, st, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    h0 = jnp.zeros((B, di, st), jnp.float32) if state is None else state["h"]
    conv0 = jnp.zeros((B, K - 1, di), x.dtype) if state is None \
        else state["conv"].astype(x.dtype)

    from repro.models import scan_utils
    ck = S if scan_utils.FULL_CHUNK_ANALYSIS else min(_CHUNK, S)
    if S % ck != 0:
        ck = S
    n_chunks = S // ck
    if n_chunks == 1:
        out, h_last, new_conv = _mixer_chunk(lp, cfg, x, h0, conv0)
    else:
        xc = jnp.moveaxis(x.reshape(B, n_chunks, ck, -1), 1, 0)

        def body(carry, xck):
            h, conv = carry
            out, h2, conv2 = _mixer_chunk(lp, cfg, xck, h, conv)
            return (h2, conv2), out

        (h_last, new_conv), outs = jax.lax.scan(body, (h0, conv0), xc)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, -1)
    new_state = None if state is None else {"h": h_last, "conv": new_conv}
    return out, new_state


def _layer_fn(cfg, x, lp, state):
    h = L.apply_norm(lp["ln"], x, cfg)
    out, new_state = _mixer(lp, cfg, h, state)
    return x + out, new_state


def _run_layers(params, cfg, x, state):
    from repro.parallel.act_sharding import constrain_residual

    def body(carry, xs):
        lp, lstate = xs
        out, ns = _layer_fn(cfg, constrain_residual(carry), lp, lstate)
        return constrain_residual(out), ns

    f = body
    if cfg.remat:
        f = jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        return jax.lax.scan(f, x, (params["layers"], state))
    new_states = []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        ls = None if state is None else jax.tree.map(lambda a: a[i], state)
        x, ns = f(x, (lp, ls))
        new_states.append(ns)
    ns = None if state is None else jax.tree.map(
        lambda *xs: jnp.stack(xs), *new_states)
    return x, ns


def forward(params, cfg, tokens):
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    x, _ = _run_layers(params, cfg, x, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    del max_len  # O(1) state — the whole point of an SSM
    L_, di, st, K = cfg.num_layers, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    return {
        "h": jnp.zeros((L_, batch, di, st), jnp.float32),
        "conv": jnp.zeros((L_, batch, K - 1, di), cfg.dtype),
    }


def prefill(params, cfg, tokens, cache):
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    x, cache = _run_layers(params, cfg, x, cache)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def decode_step(params, cfg, token, cache, pos_idx):
    del pos_idx  # stateful — position is implicit in the carried state
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    x, cache = _run_layers(params, cfg, x, cache)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def cache_axes(cfg: ModelConfig):
    return {"h": ("layers", "batch", "inner", "state"),
            "conv": ("layers", "batch", None, "inner")}
