"""Mixture-of-Experts decoder (arctic-480b: 128e top-2 + dense residual;
dbrx-132b: 16e top-4).

Expert dispatch is GShard-style: tokens are split into groups, routed with
top-k gating under a capacity factor, and moved with one-hot einsum
dispatch/combine tensors. Under pjit the expert dimension is sharded over
the "model" mesh axis (expert parallelism); GSPMD turns the dispatch
einsums into the all-to-all pattern. The paper's WS-OCS applies to the
expert GEMMs directly — each expert's (d × ff) panel is a weight column
panel (DESIGN.md §4).
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


def _build_moe_ffn(mk: L.Maker, cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "gate": mk.param("gate", (d, e), ("embed", None)),
        "wg": mk.param("wg", (e, d, f), ("experts", "embed", "mlp")),
        "wi": mk.param("wi", (e, d, f), ("experts", "embed", "mlp")),
        "wo": mk.param("wo", (e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.moe_dense_ff:
        p["dense"] = L.make_mlp(mk, cfg, d_ff=cfg.moe_dense_ff)
    return p


def _build_layer(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.make_norm(mk, cfg),
        "attn": L.make_attention(mk, cfg),
        "ln2": L.make_norm(mk, cfg),
        "moe": _build_moe_ffn(mk, cfg),
    }


def build(mk: L.Maker, cfg: ModelConfig) -> Dict:
    return {
        "embed": L.make_embedding(mk, cfg),
        "layers": mk.stack(cfg.num_layers,
                           functools.partial(_build_layer, cfg=cfg)),
        "ln_f": L.make_norm(mk, cfg),
    }


def init(rng, cfg):
    return build(L.InitMaker(rng, cfg.dtype), cfg)


def axes(cfg):
    return build(L.AxesMaker(), cfg)


def _route(probs: jax.Array, k: int, cap: int):
    """GShard iterative top-k routing. probs (G, S, E) → dispatch
    (G,S,E,C) one-hot and combine (G,S,E,C) gate-weighted. Only
    (G,S,E[,C])-sized tensors are materialized (never a k×E×C blowup)."""
    G, S, E = probs.shape
    remaining = probs
    counts = jnp.zeros((G, 1, E), jnp.float32)    # slots used per expert
    dispatch = jnp.zeros((G, S, E, cap), jnp.float32)
    combine = jnp.zeros((G, S, E, cap), jnp.float32)
    gate_total = jnp.zeros((G, S), jnp.float32)
    picks = []
    for _ in range(k):                            # k is small & static
        idx = jnp.argmax(remaining, axis=-1)      # (G, S)
        mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)
        gate = jnp.sum(probs * mask, axis=-1)     # (G, S)
        pos = jnp.cumsum(mask, axis=1) - mask + counts   # (G, S, E)
        pos_tok = jnp.sum(pos * mask, axis=-1)    # (G, S)
        keep = (pos_tok < cap).astype(jnp.float32)
        cap_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap,
                                dtype=jnp.float32)        # (G, S, C)
        slot = mask[..., None] * cap_oh[:, :, None, :] \
            * keep[..., None, None]               # (G, S, E, C)
        dispatch = dispatch + slot
        picks.append((gate, slot))
        gate_total = gate_total + gate
        counts = counts + jnp.sum(mask * keep[..., None], axis=1,
                                  keepdims=True)
        remaining = remaining * (1.0 - mask)
    norm = jnp.maximum(gate_total, 1e-9)
    for gate, slot in picks:
        combine = combine + (gate / norm)[..., None, None] * slot
    return dispatch, combine


def _constrain_ep(xe: jax.Array) -> jax.Array:
    """REPRO_OPT_EPMOE=1: pin the dispatched token buffer (G, E, C, d) to
    expert-parallel layout — E over "data" (matching the expert weights'
    sharding) so GSPMD moves TOKENS to experts (one all-to-all) instead of
    all-gathering expert weight panels (EXPERIMENTS.md §Perf)."""
    # NOTE (§Perf, refuted hypothesis): pinning E here ping-pongs
    # reshardings against the data-sharded token groups (2.2x MORE wire);
    # the winning form is the rules-only experts→"model" layout
    # (REPRO_OPT_EPMODEL) with no activation constraint.
    axis = "data" if os.environ.get("REPRO_OPT_EPMOE") == "1" else None
    if axis is None or xe.ndim != 4:
        return xe
    from jax.sharding import PartitionSpec as P
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.shape or axis not in mesh.shape:
        return xe
    if xe.shape[1] % mesh.shape[axis] != 0:
        return xe
    return jax.lax.with_sharding_constraint(xe, P(None, axis, None, None))


def apply_moe_ffn(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x (B, S, d) → (B, S, d). GShard grouped top-k einsum dispatch;
    groups of ~512 tokens keep the per-expert capacity (and the dispatch
    tensors) small."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T_tot = B * S
    Sg = 512
    while T_tot % Sg != 0:
        Sg //= 2
    G = T_tot // Sg
    cap = max(k, int(Sg * k * cfg.capacity_factor / E) + 1)

    xt = x.reshape(G, Sg, d)
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        p["gate"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch, combine = _route(probs, k, cap)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(cfg.dtype),
                    xt.astype(cfg.dtype))                    # (G,E,cap,d)
    xe = _constrain_ep(xe)       # expert-parallel all-to-all (§Perf opt)
    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(cfg.dtype)))
    hu = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(cfg.dtype))
    he = jnp.einsum("gecf,efd->gecd", hg * hu, p["wo"].astype(cfg.dtype))
    he = _constrain_ep(he)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(cfg.dtype), he)

    out = out.reshape(B, S, d)
    if "dense" in p:                                          # arctic residual
        out = out + L.apply_mlp(p["dense"], cfg, x)
    return out


def _layer_fn(cfg, x, pos, lp, cache, cache_index):
    h = L.apply_norm(lp["ln1"], x, cfg)
    attn_out, new_cache = L.apply_attention(lp["attn"], cfg, h, pos,
                                            causal=True, cache=cache,
                                            cache_index=cache_index)
    x = x + attn_out
    x = x + apply_moe_ffn(lp["moe"], cfg,
                          L.apply_norm(lp["ln2"], x, cfg))
    return x, new_cache


def _run_layers(params, cfg, x, pos, cache, cache_index):
    from repro.parallel.act_sharding import constrain_residual

    def body(carry, xs):
        lp, lcache = xs
        out, new_cache = _layer_fn(cfg, constrain_residual(carry), pos, lp,
                                   lcache, cache_index)
        return constrain_residual(out), new_cache

    f = body
    if cfg.remat:
        f = jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        return jax.lax.scan(f, x, (params["layers"], cache))
    new_caches = []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        lc = None if cache is None else jax.tree.map(lambda a: a[i], cache)
        x, nc = f(x, (lp, lc))
        new_caches.append(nc)
    nc = None if cache is None else jax.tree.map(
        lambda *xs: jnp.stack(xs), *new_caches)
    return x, nc


def forward(params, cfg, tokens):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _run_layers(params, cfg, x, pos, None, None)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg)


init_cache = T.init_cache
init_paged_cache = T.init_paged_cache
cache_axes = T.cache_axes
paged_cache_axes = T.paged_cache_axes


def prefill(params, cfg, tokens, cache):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, cache = _run_layers(params, cfg, x, pos, cache, 0)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache


def prefill_chunk(params, cfg, tokens, cache, start):
    """Chunked paged prefill (see transformer.prefill_chunk; attention
    goes block-table-direct through ``ops.paged_flash_prefill``, §11).
    NOTE: GShard capacity competition is grouping-dependent — chunked
    prefill is token-exact versus whole-prompt prefill only while the
    expert capacity never binds (DESIGN.md §10)."""
    B, C = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.dtype)
    pos = start.reshape(B)[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    x, cache = _run_layers(params, cfg, x, pos, cache, start.reshape(B))
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x, cfg), cache


def decode_step(params, cfg, token, cache, pos_idx):
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, cfg.dtype)
    if hasattr(pos_idx, "ndim") and pos_idx.ndim == 1:   # per-slot (B,)
        pos = pos_idx[:, None]
    else:
        pos = jnp.broadcast_to(pos_idx[None, None], (B, 1))
    x, cache = _run_layers(params, cfg, x, pos, cache, pos_idx)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return L.lm_logits(params["embed"], x[:, -1], cfg), cache
