"""Paged flash-prefill kernel (DESIGN.md §11).

Chunked prefill attends a chunk of C queries at absolute positions
``start[b]..start[b]+C-1`` over the request's whole written prefix. The
PR 5 path assembled that prefix by gathering the block pool through the
block table into a dense ``(B, NBMAX·BS, Hkv, D)`` copy and running the
materialized-score oracle over it — the one attention in the serve hot
loop that left the Pallas kernel family, and the copy re-densified
exactly the prefix-cache blocks the pool exists to share.

This kernel keeps chunk-prefill attention resident: the per-request
block table rides in as a *scalar-prefetch* operand (the same one-level
indirection idiom as ``paged_attention_decode.py``) so the K/V BlockSpec
index maps fetch pool blocks directly — KV streams through VMEM one
``(BS, D)`` tile at a time, StreamDCIM-style, and no dense prefix copy
ever exists in HBM. The grid is ``(B·H, C//bq, NBMAX)`` with the flash
running-(m, ℓ, acc) state of ``flash_attention.py`` in VMEM scratch.

Masking: the offset-causal mask ``kpos <= start[b] + i`` alone bounds
validity — the chunk's own K/V is written to the pool before the kernel
runs, so the newest query IS the newest written key; stale pool
contents, null-block padding past a request's table, and final-chunk
padding rows all fall in the masked future (padding rows' outputs are
garbage-but-unread, exactly as in the PR 5 oracle path). Table slots
past the written prefix still cost a (skipped) grid step: the causal
block-level skip prunes their compute, the same trick the dense flash
kernel uses for future query blocks.

LUT mode uses the flash running rescale (one LUT-exp per block plus a
LUT-exp correction), matching ``flash_attention``'s algebra — NOT the
two-sweep exact-global-max structure of the decode kernels, so LUT-mode
outputs agree with the grouped oracle only to LUT tolerance (DESIGN.md
§7/§11); exact-exp mode matches to fp32 round-off.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fusion import LUT_HI, LUT_LO, LUT_SEGMENTS, build_exp_lut
from repro.kernels import pallas_compat as pltpu
from repro.kernels.group_softmax import _lut_exp_block

_NEG = -1e30


def _kernel(bt_ref, q_ref, k_ref, v_ref, start_ref, ab_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, window, use_lut, bs, bq):
    qi, ji = pl.program_id(1), pl.program_id(2)
    nb_max = pl.num_programs(2)

    @pl.when(ji == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[0, 0]

    # ---- offset-causal block-level skip: logical block ji holds keys
    # ji·BS..ji·BS+BS-1; skip blocks fully past this query block's newest
    # row (prefix-cache hits never even touch the pruned pool blocks) ----
    q_last = start + qi * bq + bq - 1
    k_first = ji * bs
    run = k_first <= q_last
    if window is not None:
        q_first = start + qi * bq
        k_last = ji * bs + bs - 1
        run = jnp.logical_and(run, k_last > q_first - window)

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

        qpos = start + qi * bq \
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 0)
        # logical position of this block = table slot ji (the index map
        # read the pool block id; positions stay in request-logical order)
        kpos = ji * bs + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 1)
        mask = kpos <= qpos
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]                               # (bq, 1)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        if use_lut:
            p = _lut_exp_block(s - m_new, ab_ref, LUT_LO, LUT_HI)
            corr = _lut_exp_block(m_prev - m_new, ab_ref, LUT_LO, LUT_HI)
        else:
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr \
            + jnp.dot(p, v_ref[0, :, 0, :].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ji == nb_max - 1)
    def _():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_flash_prefill(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, start: jax.Array, *,
                        window: Optional[int] = None, use_lut: bool = False,
                        scale: Optional[float] = None, block_q: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q (B, H, C, D) chunk queries; k_pool/v_pool (NB, BS, Hkv, D) shared
    block pools; block_tables (B, NBMAX) int32 pool-block ids per logical
    block (pad with 0 — the null block); start (B,) int32 absolute
    position of each chunk's first query. Returns (B, H, C, D). The KV
    tile is the pool block size BS; C must divide by min(block_q, C)."""
    B, H, C, D = q.shape
    NB, BS, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    rep = H // Hkv
    nbmax = block_tables.shape[1]
    bq = min(block_q, C)
    if C % bq != 0:
        raise ValueError(
            f"paged_flash_prefill: grid cannot tile q {tuple(q.shape)} "
            f"over pools {tuple(k_pool.shape)} — chose block_q={bq} "
            f"(requested {block_q}) for chunk C={C}; pad the chunk to "
            "a multiple of block_q")
    scale = scale if scale is not None else D ** -0.5

    q3 = q.reshape(B * H, C, D)
    bt = block_tables.astype(jnp.int32)
    st = start.reshape(B, 1).astype(jnp.int32)
    a, b = build_exp_lut()
    ab = jnp.stack([a, b], axis=1)

    def kv_head(h):
        return (h % H) // rep

    kern = functools.partial(_kernel, scale=scale, window=window,
                             use_lut=use_lut, bs=BS, bq=bq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, C // bq, nbmax),          # (bh, q block, logical blk)
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ji, bt: (h, qi, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda h, qi, ji, bt: (bt[h // H, ji], 0,
                                                kv_head(h), 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda h, qi, ji, bt: (bt[h // H, ji], 0,
                                                kv_head(h), 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ji, bt: (h // H, 0)),
            pl.BlockSpec((LUT_SEGMENTS, 2), lambda h, qi, ji, bt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ji, bt: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),     # running accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, C, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(bt, q3, k_pool, v_pool, st, ab)
    return out.reshape(B, H, C, D)
