"""Pallas-TPU name-compatibility shims (DESIGN.md §6).

jax renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams`` and
``pltpu.TPUMemorySpace`` → ``pltpu.MemorySpace`` after 0.4.37. Kernel
modules import these names from here instead of from ``pltpu`` so they
lower on both sides of the rename. The stable names (``VMEM``, ``SMEM``,
``SemaphoreType``, ``make_async_copy``) are re-exported for uniformity —
kernel code should not need a direct ``pltpu`` import for any of them.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

VMEM = pltpu.VMEM
SMEM = pltpu.SMEM
SemaphoreType = pltpu.SemaphoreType
make_async_copy = pltpu.make_async_copy
PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec

__all__ = ["CompilerParams", "MemorySpace", "VMEM", "SMEM",
           "SemaphoreType", "make_async_copy", "PrefetchScalarGridSpec"]
