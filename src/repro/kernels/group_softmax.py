"""Group-Softmax Pallas kernel with 64-segment LUT exp (paper §II-D, eq 1).

One pass over a row block computes, per group of ``group_size`` elements:
the group max (offset — kills the global-max dependency), the LUT-exp of
every element ("partial accumulation": all groups exponentiate in
parallel on the VPU), and the per-group sum ("full accumulation"); groups
are then merged online and the normalization is fused into the final
scale.

The LUT lookup is realized as a one-hot × (64, 2) coefficient matmul on
the MXU — the TPU analogue of the CIM array storing (a, b) per segment and
selecting a row by wordline activation (DESIGN.md §8.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

from repro.core.fusion import LUT_HI, LUT_LO, LUT_SEGMENTS, build_exp_lut


def _lut_exp_block(x: jax.Array, ab_ref, lo: float, hi: float) -> jax.Array:
    """Piecewise-linear exp via one-hot matmul against the (64, 2) LUT
    (the TPU analogue of CIM wordline-selected coefficients). Underflow
    below ``lo`` flushes to an exact 0, matching ``fusion.lut_exp``."""
    segments = ab_ref.shape[0]
    xc = jnp.clip(x, lo, hi)
    seg_w = (hi - lo) / segments
    idx = jnp.clip(((xc - lo) / seg_w).astype(jnp.int32), 0, segments - 1)
    flat = idx.reshape(-1, 1)
    onehot = (flat == jax.lax.broadcasted_iota(jnp.int32, (1, segments), 1))
    ab = jnp.dot(onehot.astype(jnp.float32), ab_ref[...],
                 preferred_element_type=jnp.float32)   # (n, 2)
    a = ab[:, 0].reshape(x.shape)
    b = ab[:, 1].reshape(x.shape)
    return jnp.where(x < lo, 0.0, a * xc + b)


def _kernel(x_ref, ab_ref, o_ref, *, group_size, lo, hi):
    br, s = x_ref.shape
    g = group_size
    G = s // g
    x = x_ref[...].astype(jnp.float32)
    xg = x.reshape(br, G, g)
    m_g = jnp.max(xg, axis=-1, keepdims=True)                 # group max
    p = _lut_exp_block(xg - m_g, ab_ref, lo, hi)              # partial acc
    s_g = jnp.sum(p, axis=-1, keepdims=True)                  # full acc
    m = jnp.max(m_g, axis=-2, keepdims=True)                  # online merge
    r = _lut_exp_block(m_g - m, ab_ref, lo, hi)
    denom = jnp.sum(s_g * r, axis=-2, keepdims=True)
    out = p * r / jnp.maximum(denom, 1e-30)
    o_ref[...] = out.reshape(br, s).astype(o_ref.dtype)


def group_softmax(x: jax.Array, group_size: int = 64, block_rows: int = 8,
                  interpret: bool = False) -> jax.Array:
    """Softmax over the last axis of ``x`` (any leading dims) in groups of
    ``group_size``, LUT-exp approximation. Last dim must be divisible by
    ``group_size`` (model code pads; see ops.py)."""
    orig_shape = x.shape
    s = orig_shape[-1]
    g = min(group_size, s)
    assert s % g == 0, (s, g)
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, s)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)

    a, b = build_exp_lut()
    ab = jnp.stack([a, b], axis=1)  # (64, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, group_size=g, lo=LUT_LO, hi=LUT_HI),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, s), lambda r: (r, 0)),
            pl.BlockSpec((LUT_SEGMENTS, 2), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, s), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, s), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, ab)
    return out.reshape(orig_shape)
