"""Paged fused attention decode kernel (DESIGN.md §10).

The PR-3 fused decode kernel (`attention_decode.py`) reads K/V from the
dense per-slot cache layout ``(B, S, Hkv, D)`` — a layout whose HBM
footprint is ``slots × max_len`` regardless of how many tokens each
request actually holds. The paged serving subsystem stores K/V as
fixed-size *blocks* in a shared pool ``(NB, BS, Hkv, D)`` and maps each
request's logical positions onto pool blocks through a per-request block
table ``(B, NBMAX)``; blocks holding a shared prompt prefix appear in
many tables but exist once in the pool.

This kernel is the paged variant of the fused decode dispatch: the block
table rides in as a *scalar-prefetch* operand so the K/V BlockSpec index
maps gather pool blocks directly — the same "BlockSpec does the layout
math" trick the dense kernel uses for GQA head sharing, extended to one
level of indirection. The grid is block-aligned ``(B·Hkv, 2, NBMAX)``
and validity is masked by the per-request ``lengths`` exactly as in the
dense kernel, so table entries past a request's last block (padded with
the reserved null block 0) contribute nothing.

Group-softmax semantics: the paper's eq-(1) grouping is capped at the
pool block size (``g = min(group_size, BS)``) because a group may not
span two pool blocks (they are not adjacent in HBM). With exact exp the
grouping is mathematically irrelevant (group softmax ≡ softmax); in LUT
mode the oracle must be called with the same effective group size to
match to fp32 round-off (``ops.paged_attention_decode`` does this when
dispatching here). The two-sweep phase structure (exact global group-max
first, then LUT-exp with late merge) is identical to the dense kernel —
see DESIGN.md §7 for why a flash-style running rescale is not exact
under the LUT.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fusion import LUT_HI, LUT_LO, LUT_SEGMENTS, build_exp_lut
from repro.kernels import pallas_compat as pltpu
from repro.kernels.group_softmax import _lut_exp_block

_NEG = -1e30


def _kernel(bt_ref, q_ref, k_ref, v_ref, len_ref, ab_ref, o_ref,
            mrun_ref, den_ref, acc_ref, *,
            scale, group, use_lut, window, bs, gq):
    ph, ji = pl.program_id(1), pl.program_id(2)
    nb_max = pl.num_programs(2)

    @pl.when((ph == 0) & (ji == 0))
    def _():
        mrun_ref[...] = jnp.full_like(mrun_ref, _NEG)
        den_ref[...] = jnp.zeros_like(den_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bs, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # logical position of this block = table slot ji (the index map read
    # the pool block id; positions stay in request-logical order)
    kpos = ji * bs + jax.lax.broadcasted_iota(jnp.int32, (gq, bs), 1)
    mask = kpos < length
    if window is not None:
        mask = jnp.logical_and(mask, kpos > length - 1 - window)
    s = jnp.where(mask, s, _NEG)
    nb = bs // group
    sg = s.reshape(gq, nb, group)
    m_g = jnp.max(sg, axis=-1)                              # (G, nb)

    @pl.when(ph == 0)
    def _():
        m_blk = jnp.max(m_g, axis=-1, keepdims=True)        # (G, 1)
        mrun_ref[...] = jnp.maximum(mrun_ref[...],
                                    jnp.broadcast_to(m_blk, mrun_ref.shape))

    @pl.when(ph == 1)
    def _():
        m = mrun_ref[:, :1]                                 # exact global max
        if use_lut:
            p = _lut_exp_block(sg - m_g[..., None], ab_ref, LUT_LO, LUT_HI)
            r = _lut_exp_block(m_g - m, ab_ref, LUT_LO, LUT_HI)
        else:
            p = jnp.exp(sg - m_g[..., None])
            r = jnp.exp(m_g - m)
        s_g = jnp.sum(p, axis=-1)                           # (G, nb)
        den = jnp.sum(s_g * r, axis=-1, keepdims=True)
        den_ref[...] = den_ref[...] + jnp.broadcast_to(den, den_ref.shape)
        pr = (p * r[..., None]).reshape(gq, bs)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bs, D)
        acc_ref[...] = acc_ref[...] + jnp.dot(
            pr, v, preferred_element_type=jnp.float32)

    @pl.when((ph == 1) & (ji == nb_max - 1))
    def _():
        den = jnp.maximum(den_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / den).astype(o_ref.dtype)


def paged_attention_decode(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *, group_size: int = 64,
                           use_lut: bool = True,
                           scale: Optional[float] = None,
                           window: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """q (B, H, D) single decode query per request; k_pool/v_pool
    (NB, BS, Hkv, D) shared block pools; block_tables (B, NBMAX) int32
    pool-block ids per logical block (pad with 0 — the null block);
    lengths (B,) or (B, 1) int32 valid token counts. Returns (B, H, D).
    The softmax group is capped at BS (see module docstring)."""
    B, H, D = q.shape
    NB, BS, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    nbmax = block_tables.shape[1]
    g = min(group_size, BS)
    assert BS % g == 0, (BS, g)
    scale = scale if scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    bt = block_tables.astype(jnp.int32)
    len2 = lengths.reshape(B, 1).astype(jnp.int32)
    a, b = build_exp_lut()
    ab = jnp.stack([a, b], axis=1)

    kern = functools.partial(_kernel, scale=scale, group=g, use_lut=use_lut,
                             window=window, bs=BS, gq=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, 2, nbmax),             # (bh, phase, logical block)
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda bh, ph, ji, bt: (bh // Hkv, bh % Hkv, 0, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda bh, ph, ji, bt: (bt[bh // Hkv, ji], 0,
                                                 bh % Hkv, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda bh, ph, ji, bt: (bt[bh // Hkv, ji], 0,
                                                 bh % Hkv, 0)),
            pl.BlockSpec((1, 1), lambda bh, ph, ji, bt: (bh // Hkv, 0)),
            pl.BlockSpec((LUT_SEGMENTS, 2), lambda bh, ph, ji, bt: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, D),
            lambda bh, ph, ji, bt: (bh // Hkv, bh % Hkv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((G, 128), jnp.float32),   # denominator
            pltpu.VMEM((G, D), jnp.float32),     # PV accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(bt, qg, k_pool, v_pool, len2, ab)
    return out.reshape(B, H, D)
