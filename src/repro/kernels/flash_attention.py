"""Online-softmax (flash) attention Pallas kernel.

The paper adopts online softmax [7] to remove the global max/denominator
dependency; this kernel is its TPU form: KV is consumed in (block_k × D)
tiles with running (m, ℓ, acc) state in VMEM scratch, so attention memory
is O(block) instead of O(S²). Supports causal masking, GQA (KV-head
sharing via the BlockSpec index map), local-window attention (for
recurrentgemma), the paper's 64-segment LUT exp mode, and per-batch
absolute query offsets (``q_offset``) for chunked prefill: queries at
absolute positions q_offset[b]..q_offset[b]+Sq-1 attend keys 0..Sk-1
under the offset-causal mask kpos <= q_offset[b] + i (DESIGN.md §11).
With q_offset the causal mask alone bounds validity — the newest query
IS the newest written key — so keys past the written prefix (stale pool
contents, chunk padding) are masked without a separate length operand.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

from repro.core.fusion import LUT_HI, LUT_LO, LUT_SEGMENTS, build_exp_lut
from repro.kernels.group_softmax import _lut_exp_block

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, off_ref, ab_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale, causal, window, use_lut, sk, bq, bk):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute position of this block's first query row (suffix alignment
    # off == sk - sq by default; chunked prefill passes per-batch offsets)
    off = off_ref[0, 0]

    # ---- causal block-level skip: block fully in the masked future ----
    q_last = off + qi * bq + bq - 1          # largest key this block sees
    k_first = ki * bk
    run = jnp.logical_or(not causal, k_first <= q_last)
    if window is not None:
        q_first = off + qi * bq
        k_last = ki * bk + bk - 1
        run = jnp.logical_and(run, k_last > q_first - window)

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, D)
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

        qpos = off + qi * bq \
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]                               # (bq, 1)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        if use_lut:
            p = _lut_exp_block(s - m_new, ab_ref, LUT_LO, LUT_HI)
            corr = _lut_exp_block(m_prev - m_new, ab_ref, LUT_LO, LUT_HI)
        else:
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr \
            + jnp.dot(p, v_ref[0].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, use_lut: bool = False,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: Optional[jax.Array] = None,
                    interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k/v (B, Hkv, Sk, D), Hkv | H. Returns (B, H, Sq, D).

    ``q_offset`` (B,) int32: absolute position of each batch row's first
    query (chunked prefill over a longer written prefix); requires
    ``causal`` — the offset-causal mask is what bounds validity. Default
    is the classic suffix alignment qpos = arange(Sq) + (Sk - Sq).

    Sequence lengths must be divisible by the block sizes (callers pad;
    the in-kernel ``kpos < sk`` mask makes KV padding safe)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq != 0 or Sk % bk != 0:
        raise ValueError(
            f"flash_attention: grid cannot tile q {tuple(q.shape)} / "
            f"k {tuple(k.shape)} — chose block_q={bq} (requested "
            f"{block_q}) for Sq={Sq}, block_k={bk} (requested "
            f"{block_k}) for Sk={Sk}; pad the sequences to multiples "
            "of the block sizes")
    assert q_offset is None or causal, "q_offset requires causal masking"
    scale = scale if scale is not None else D ** -0.5

    q3 = q.reshape(B * H, Sq, D)
    k3 = k.reshape(B * Hkv, Sk, D)
    v3 = v.reshape(B * Hkv, Sk, D)
    if q_offset is None:
        off = jnp.full((B, 1), Sk - Sq, jnp.int32)
    else:
        off = q_offset.reshape(B, 1).astype(jnp.int32)

    def kv_head(h):
        return (h // H) * Hkv + (h % H) // rep

    a, b = build_exp_lut()
    ab = jnp.stack([a, b], axis=1)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, use_lut=use_lut, sk=Sk,
                             bq=bq, bk=bk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qi, ki: (kv_head(h), ki, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qi, ki: (kv_head(h), ki, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki: (h // H, 0)),
            pl.BlockSpec((LUT_SEGMENTS, 2), lambda h, qi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),     # running accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q3, k3, v3, off, ab)
    return out.reshape(B, H, Sq, D)
