"""Fused elementwise linear-recurrence Pallas kernel: h_t = a_t⊙h_{t−1} + b_t.

The RG-LRU (recurrentgemma) and any gated elementwise recurrence lower to
this primitive. Like ``selective_scan``, the within-tile associative scan
runs entirely in VMEM with the running state carried in scratch across
sequence tiles, so HBM sees only a, b, and y once each — the log-depth
scan intermediates never hit HBM (the memory term of the hybrid train
cell in EXPERIMENTS.md §Roofline).

Grid: (B, D/bd, S/bs), sequence innermost ("arbitrary").
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def _kernel(a_ref, b_ref, h0_ref, o_ref, hout_ref, h_ref):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)      # (bs, bd)
    b = b_ref[0].astype(jnp.float32)
    b = b.at[0].add(a[0] * h_ref[0])      # fold the carried state
    _, hs = jax.lax.associative_scan(_combine, (a, b), axis=0)
    h_ref[...] = hs[-1:]
    o_ref[0] = hs.astype(o_ref.dtype)

    @pl.when(si == ns - 1)
    def _():
        hout_ref[...] = h_ref[...]


def linear_recurrence_kernel(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                             block_s: int = 128, block_d: int = 256,
                             interpret: bool = False
                             ) -> Tuple[jax.Array, jax.Array]:
    """a, b: (B, S, D); h0: (B, D). Returns (h (B,S,D) f32, h_last (B,D))."""
    B, S, D = a.shape
    bs = min(block_s, S)
    bd = min(block_d, D)
    assert S % bs == 0 and D % bd == 0, (S, bs, D, bd)

    return pl.pallas_call(
        _kernel,
        grid=(B, D // bd, S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda bi, d, s: (bi, s, d)),
            pl.BlockSpec((1, bs, bd), lambda bi, d, s: (bi, s, d)),
            pl.BlockSpec((1, bd), lambda bi, d, s: (bi, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda bi, d, s: (bi, s, d)),
            pl.BlockSpec((1, bd), lambda bi, d, s: (bi, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
