"""Group-RMSNorm / group-LayerNorm Pallas kernels (paper §II-D, eq 2).

Per row: per-group partial statistics (Σx², and Σx for the LN variant) are
computed in parallel, merged to the global statistic late, and the
normalization is applied *fused with the γ (and β) scaling* in the same
VMEM-resident pass — the paper's "synchronization together with γ scaling".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _rms_kernel(x_ref, g_ref, o_ref, *, group_size, eps):
    br, n = x_ref.shape
    G = n // group_size
    x = x_ref[...].astype(jnp.float32)
    xg = x.reshape(br, G, group_size)
    partial_ms = jnp.mean(jnp.square(xg), axis=-1)      # per-group stat
    global_ms = jnp.mean(partial_ms, axis=-1, keepdims=True)  # late sync
    inv = jax.lax.rsqrt(global_ms + eps)
    o_ref[...] = (x * inv * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, group_size, eps):
    br, n = x_ref.shape
    G = n // group_size
    x = x_ref[...].astype(jnp.float32)
    xg = x.reshape(br, G, group_size)
    s1 = jnp.sum(xg, axis=-1)
    s2 = jnp.sum(jnp.square(xg), axis=-1)
    mean = jnp.sum(s1, axis=-1, keepdims=True) / n
    var = jnp.sum(s2, axis=-1, keepdims=True) / n - jnp.square(mean)
    inv = jax.lax.rsqrt(var + eps)
    out = (x - mean) * inv * g_ref[...].astype(jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def _run(kernel, x, scale_args, block_rows, interpret):
    orig_shape = x.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    in_specs = [pl.BlockSpec((br, n), lambda r: (r, 0))]
    args = [x2]
    for s in scale_args:
        in_specs.append(pl.BlockSpec((1, n), lambda r: (0, 0)))
        args.append(s.reshape(1, n))
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, n), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return out.reshape(orig_shape)


def group_rmsnorm(x: jax.Array, gamma: jax.Array, group_size: int = 128,
                  eps: float = 1e-6, block_rows: int = 8,
                  interpret: bool = False) -> jax.Array:
    n = x.shape[-1]
    g = min(group_size, n)
    assert n % g == 0, (n, g)
    k = functools.partial(_rms_kernel, group_size=g, eps=eps)
    return _run(k, x, [gamma], block_rows, interpret)


def group_layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                    group_size: int = 128, eps: float = 1e-5,
                    block_rows: int = 8, interpret: bool = False) -> jax.Array:
    n = x.shape[-1]
    g = min(group_size, n)
    assert n % g == 0, (n, g)
    k = functools.partial(_ln_kernel, group_size=g, eps=eps)
    return _run(k, x, [gamma, beta], block_rows, interpret)
