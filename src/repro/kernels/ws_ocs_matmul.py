"""WS-OCS quantized matmul Pallas kernel (paper §II-B + §II-C on TPU).

out[M, K] = x[M, N] @ dequant(w[N, K]) with INT4/INT8 nibble-packed
weights and per-group scales.

TPU mapping of the paper's mechanisms (DESIGN.md §2):

* **WS-OCS loop order** — grid = (K/bk, M/bm) with the weight *column
  panel* index outermost. The weight BlockSpec index map ignores the inner
  ``m`` index, so the Pallas pipeline fetches each (N × bk) panel from HBM
  exactly once (NK total weight traffic — Table I's WS-OCS row), keeps it
  VMEM-resident while *all* input row-tiles stream past (the input-reuse
  buffer), and the (bm × bk) fp32 accumulation happens in registers/VMEM
  (the partial-sum buffer). Weights are replaced only after every input
  has been processed — the paper's stated replacement policy.

* **RCW** — ``rcw_matmul`` keeps weights in HBM (``MemorySpace.ANY``) and
  manually double-buffers the panel with ``make_async_copy``: the DMA for
  panel k+1 is issued at the *first* inner step of panel k and waited on
  only when panel k+1 begins — fill hides behind the M/bm compute steps,
  exactly the paper's Phase-1/Phase-2 overlap. ``rcw=False`` issues a
  blocking copy per panel (the paper's serial baseline).

* **Dual INT4/INT8** — int4 weights travel nibble-packed (two per byte)
  through HBM and VMEM, preserving INT4 traffic economics; dequant happens
  at the MXU boundary (no native INT4 MACs on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def check_tileable(kernel_name: str, x_shape, w_shape, m_dim: int, bm: int,
                   req_bm: int, k_dim: int, bk: int, req_bk: int) -> None:
    """RAISE (matching the PR 7 attention-kernel error style) when the
    (K/bk, M/bm) grid cannot tile the problem — reporting the offending
    shapes and the chosen block sizes instead of a bare assert."""
    if m_dim % bm != 0 or k_dim % bk != 0:
        raise ValueError(
            f"{kernel_name}: grid cannot tile x {tuple(x_shape)} / w "
            f"{tuple(w_shape)} — chose bm={bm} (requested {req_bm}) for "
            f"M={m_dim}, bk={bk} (requested {req_bk}) for K={k_dim}, but "
            f"M % bm == {m_dim % bm} and K % bk == {k_dim % bk}; pad the "
            "operands or pass dividing block sizes (the hot loop must "
            "not densify)")


def _dequant_block(w_blk: jax.Array, scale_blk: jax.Array, bits: int,
                   n: int) -> jax.Array:
    """(Np, bk) packed/int8 block + (G, bk) scales → (N, bk) f32."""
    if bits == 4:
        lo = (w_blk & 0xF).astype(jnp.int8)
        hi = ((w_blk >> 4) & 0xF).astype(jnp.int8)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=1).reshape(n, w_blk.shape[-1])
    else:
        q = w_blk
    g = scale_blk.shape[0]
    sf = jnp.repeat(scale_blk, n // g, axis=0)
    return q.astype(jnp.float32) * sf


# ---------------------------------------------------------------------------
# Variant A: pipelined BlockSpec kernel (production path; RCW overlap is
# provided by the Pallas pipeline's implicit double-buffering)
# ---------------------------------------------------------------------------

def _panel_kernel(x_ref, w_ref, s_ref, xs_ref, o_ref, *, bits, n):
    w = _dequant_block(w_ref[...], s_ref[...], bits, n)
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if xs_ref is not None:
        acc = acc * xs_ref[...].astype(jnp.float32)
    o_ref[...] = acc


def ws_ocs_matmul(x: jax.Array, w_data: jax.Array, w_scale: jax.Array, *,
                  bits: int = 4, x_scale: Optional[jax.Array] = None,
                  bm: int = 128, bk: int = 128,
                  interpret: bool = False) -> jax.Array:
    """Panel-stationary quantized matmul. x (M,N) int8/float; w_data
    (N//2,K) uint8 or (N,K) int8; w_scale (G,K) f32; out (M,K) f32."""
    M, N = x.shape
    K = w_data.shape[1]
    Np = w_data.shape[0]            # N//2 when packed
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("ws_ocs_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)

    grid = (K // bk, M // bm)       # weight-panel index OUTERMOST (WS-OCS)
    kernel = functools.partial(_panel_kernel, bits=bits, n=N)
    in_specs = [
        pl.BlockSpec((bm, N), lambda k, m: (m, 0)),       # input-reuse buf
        pl.BlockSpec((Np, bk), lambda k, m: (0, k)),      # stationary panel
        pl.BlockSpec((G, bk), lambda k, m: (0, k)),
    ]
    args = [x, w_data, w_scale]
    if x_scale is not None:
        in_specs.append(pl.BlockSpec((bm, 1), lambda k, m: (m, 0)))
        args.append(x_scale)
        wrapped = kernel
    else:
        wrapped = lambda xr, wr, sr, orf: kernel(xr, wr, sr, None, orf)

    return pl.pallas_call(
        wrapped,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda k, m: (m, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Variant C: fused-epilogue / fused-prologue family (DESIGN.md §7)
#
# The paper's operator-fusion claim (Fig 9b) is that the nonlinear stages
# ride inside the GEMM pipeline instead of round-tripping fp32 tensors
# through HBM. ``fused_matmul`` realizes that on TPU: while the (bm × bk)
# accumulator is still in VMEM it applies, in order,
#
#   prologue   group-RMSNorm of the input row tile (paper eq 2 — the
#              per-group partial Σx² is computed on the already-loaded
#              (bm × N) tile, so the pre-norm costs zero extra HBM reads)
#   epilogue   activation-scale multiply → SiLU/GELU (optionally GLU-gated
#              by a second GEMM against the *same* resident input tile)
#              → bias add → residual add → optional re-quantization to
#              int8 for the next W4A8 GEMM.
#
# Every stage is optional and composable; the unfused reference is the
# same stages as separate jnp ops (ref.fused_matmul_ref).
# ---------------------------------------------------------------------------

def _apply_act(acc: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(acc)
    if act == "gelu":
        return jax.nn.gelu(acc)
    assert act == "none", act
    return acc


def _fused_kernel(refs, *, bits, n, act, has, norm_group, norm_eps):
    """refs arrive in the fixed order [x, w, s] + optional
    [gamma, x_scale, w2, s2, bias, residual, out_scale] + [out]."""
    it = iter(refs)
    x_ref, w_ref, s_ref = next(it), next(it), next(it)
    g_ref = next(it) if has["gamma"] else None
    xs_ref = next(it) if has["x_scale"] else None
    w2_ref = next(it) if has["glu"] else None
    s2_ref = next(it) if has["glu"] else None
    b_ref = next(it) if has["bias"] else None
    r_ref = next(it) if has["residual"] else None
    q_ref = next(it) if has["requant"] else None
    o_ref = next(it)

    x = x_ref[...].astype(jnp.float32)                     # (bm, N)
    if g_ref is not None:
        # group-RMSNorm prologue on the resident row tile (eq 2)
        bm_, n_ = x.shape
        xg = x.reshape(bm_, n_ // norm_group, norm_group)
        partial_ms = jnp.mean(jnp.square(xg), axis=-1)
        global_ms = jnp.mean(partial_ms, axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(global_ms + norm_eps) \
            * g_ref[...].astype(jnp.float32)

    w = _dequant_block(w_ref[...], s_ref[...], bits, n)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if xs_ref is not None:
        acc = acc * xs_ref[...].astype(jnp.float32)

    if w2_ref is not None:
        # GLU gate: second GEMM against the same resident input tile
        w2 = _dequant_block(w2_ref[...], s2_ref[...], bits, n)
        acc2 = jnp.dot(x, w2, preferred_element_type=jnp.float32)
        if xs_ref is not None:
            acc2 = acc2 * xs_ref[...].astype(jnp.float32)
        acc = _apply_act(acc, act) * acc2
    else:
        acc = _apply_act(acc, act)

    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    if r_ref is not None:
        acc = acc + r_ref[...].astype(jnp.float32)

    if q_ref is not None:
        # re-quantize for the next W4A8 GEMM while still in VMEM
        q = jnp.round(acc / q_ref[...].astype(jnp.float32))
        o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
    else:
        o_ref[...] = acc


def fused_matmul(x: jax.Array, w_data: jax.Array, w_scale: jax.Array, *,
                 bits: int = 4, gamma: Optional[jax.Array] = None,
                 norm_group: int = 128, norm_eps: float = 1e-6,
                 x_scale: Optional[jax.Array] = None, act: str = "none",
                 w2_data: Optional[jax.Array] = None,
                 w2_scale: Optional[jax.Array] = None,
                 bias: Optional[jax.Array] = None,
                 residual: Optional[jax.Array] = None,
                 out_scale: Optional[jax.Array] = None,
                 bm: int = 128, bk: int = 128,
                 interpret: bool = False) -> jax.Array:
    """WS-OCS matmul with fused prologue/epilogues (DESIGN.md §7).

    x (M, N); w_data/w2_data packed-int4 (N//2, K) or int8 (N, K);
    w_scale/w2_scale (G, K); gamma (N,) enables the group-RMSNorm
    prologue; x_scale (M, 1) per-row activation dequant; bias (K,);
    residual (M, K); out_scale (M, 1) enables the int8 re-quantization
    epilogue (output dtype int8). Output (M, K) f32 (or int8)."""
    M, N = x.shape
    K = w_data.shape[1]
    Np = w_data.shape[0]
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("fused_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)
    if gamma is not None:
        norm_group = min(norm_group, N)
        assert N % norm_group == 0, (N, norm_group)
    if w2_data is not None:
        assert w2_data.shape == w_data.shape, (w2_data.shape, w_data.shape)
        assert w2_scale is not None

    has = {"gamma": gamma is not None, "x_scale": x_scale is not None,
           "glu": w2_data is not None, "bias": bias is not None,
           "residual": residual is not None,
           "requant": out_scale is not None}

    in_specs = [
        pl.BlockSpec((bm, N), lambda k, m: (m, 0)),       # input-reuse buf
        pl.BlockSpec((Np, bk), lambda k, m: (0, k)),      # stationary panel
        pl.BlockSpec((G, bk), lambda k, m: (0, k)),
    ]
    args = [x, w_data, w_scale]
    if has["gamma"]:
        in_specs.append(pl.BlockSpec((1, N), lambda k, m: (0, 0)))
        args.append(gamma.reshape(1, N))
    if has["x_scale"]:
        in_specs.append(pl.BlockSpec((bm, 1), lambda k, m: (m, 0)))
        args.append(x_scale)
    if has["glu"]:
        in_specs.append(pl.BlockSpec((Np, bk), lambda k, m: (0, k)))
        in_specs.append(pl.BlockSpec((G, bk), lambda k, m: (0, k)))
        args.extend([w2_data, w2_scale])
    if has["bias"]:
        in_specs.append(pl.BlockSpec((1, bk), lambda k, m: (0, k)))
        args.append(bias.reshape(1, K))
    if has["residual"]:
        in_specs.append(pl.BlockSpec((bm, bk), lambda k, m: (m, k)))
        args.append(residual)
    if has["requant"]:
        in_specs.append(pl.BlockSpec((bm, 1), lambda k, m: (m, 0)))
        args.append(out_scale)

    out_dtype = jnp.int8 if has["requant"] else jnp.float32
    kernel = functools.partial(_fused_kernel, bits=bits, n=N, act=act,
                               has=has, norm_group=norm_group,
                               norm_eps=norm_eps)
    return pl.pallas_call(
        lambda *refs: kernel(refs),
        grid=(K // bk, M // bm),                # WS-OCS order (k outermost)
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda k, m: (m, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Variant B: manual double-buffered RCW kernel (explicit Phase-1/Phase-2)
# ---------------------------------------------------------------------------

def _rcw_kernel(w_hbm, x_ref, s_ref, o_ref, wbuf, sems, *, bits, n, bk,
                rcw: bool):
    k, m = pl.program_id(0), pl.program_id(1)
    nk = pl.num_programs(0)

    def panel_copy(ki, slot):
        return pltpu.make_async_copy(
            w_hbm.at[:, pl.ds(ki * bk, bk)], wbuf.at[slot], sems.at[slot])

    if rcw:
        # Phase 1 (k==0, first panel): blocking fill of slot 0.
        @pl.when((k == 0) & (m == 0))
        def _():
            cp = panel_copy(0, 0)
            cp.start()
            cp.wait()

        # Phase 2: at the first compute step of panel k, issue the DMA for
        # panel k+1 into the other slot — it completes while the MXU works
        # through all M/bm input tiles of panel k (weight update hidden).
        @pl.when((m == 0) & (k + 1 < nk))
        def _():
            panel_copy(k + 1, (k + 1) % 2).start()

        # Wait for this panel's fill (issued during panel k-1's compute).
        @pl.when((m == 0) & (k > 0))
        def _():
            panel_copy(k, k % 2).wait()
    else:
        # Serial baseline: blocking fill before each panel's compute.
        @pl.when(m == 0)
        def _():
            cp = panel_copy(k, k % 2)
            cp.start()
            cp.wait()

    w = _dequant_block(wbuf[k % 2], s_ref[...], bits, n)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


def rcw_matmul(x: jax.Array, w_data: jax.Array, w_scale: jax.Array, *,
               bits: int = 4, bm: int = 128, bk: int = 128, rcw: bool = True,
               interpret: bool = False) -> jax.Array:
    """Explicit-RCW variant: weights stay in HBM; the kernel double-buffers
    (N × bk) panels in VMEM scratch with async DMA. ``rcw`` toggles the
    overlap (paper ablation)."""
    M, N = x.shape
    K = w_data.shape[1]
    Np = w_data.shape[0]
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("rcw_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)

    grid = (K // bk, M // bm)
    kernel = functools.partial(_rcw_kernel, bits=bits, n=N, bk=bk, rcw=rcw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY),  # weights: HBM
            pl.BlockSpec((bm, N), lambda k, m: (m, 0)),
            pl.BlockSpec((G, bk), lambda k, m: (0, k)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda k, m: (m, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, Np, bk), w_data.dtype),   # double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(w_data, x, w_scale)
