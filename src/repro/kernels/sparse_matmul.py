"""Sparsity-aware WS-OCS matmul kernels: structured N:M compressed
weights through the same VMEM-resident pipeline (DESIGN.md §14).

The dense trio (``ws_ocs_matmul`` / ``fused_matmul`` / ``rcw_matmul``)
streams (N × bk) weight panels; these variants stream the COMPRESSED
(Nc × bk) panel, Nc = N·n/m, plus compact metadata — so every panel DMA
moves ~n/m of the dense weight bytes (the paper's weight-update latency
shrinks by the sparsity factor) and the zero groups never occupy VMEM.

Two metadata forms, recovered from the tensor's rank:

* **col** (ndim 2) — per-output-column N:M bitmask, uint8 (N//8, K).
  The kernel expands the compressed values back to a dense (N, bk) tile
  in VMEM with a rank/cumsum select over the m-groups (an n-step static
  loop — no gather), then runs the dense MXU pipeline. Savings are in
  HBM→VMEM panel traffic: 0.5·4 + 1 = 3 bits/element for w4 2:4.
* **row** (ndim 1) — flexible per-row N-of-M: the kept-row index vector
  int32 (Nc,) is SCALAR-PREFETCHED (same mechanism as the paged
  attention block tables); the kernel gathers the kept activation
  columns and contracts only Nc rows — the dropped rows' MACs are
  genuinely skipped (~m/n fewer) on top of the panel-byte savings.

``accum="int32"`` selects the bit-deterministic int-accumulation mode
(int8 x, integer dot per scale group, fixed-order f32 scale chain —
``ref.int_group_matmul_ref``): kernel output is bit-identical to the
dense-mask reference for any tiling. ``"f32"`` matches to round-off.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu
from repro.kernels import ref as _ref
from repro.kernels.ws_ocs_matmul import _apply_act, check_tileable


def _unpack_vals(v_blk: jax.Array, bits: int, nc: int) -> jax.Array:
    """(Ncp, bk) packed/int8 compressed values → (Nc, bk) int8 codes."""
    if bits != 4:
        return v_blk
    lo = (v_blk & 0xF).astype(jnp.int8)
    hi = ((v_blk >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.stack([lo, hi], axis=1).reshape(nc, v_blk.shape[-1])


def _expand_col_block(v_blk: jax.Array, b_blk: jax.Array, *, bits: int,
                      n: int, m: int, n_rows: int) -> jax.Array:
    """Compressed (Ncp, bk) values + (N//8, bk) bitmask → dense (N, bk)
    int8 codes, zeros in pruned slots. Gather-free: the r-th kept value
    of each m-group lands where the mask's exclusive cumsum equals r."""
    bk = v_blk.shape[-1]
    nc = n_rows * n // m
    vq = _unpack_vals(v_blk, bits, nc)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    msk = ((b_blk[:, None, :] >> shifts) & 1).astype(jnp.int32)
    msk = msk.reshape(n_rows, bk)
    g2 = n_rows // m
    mg = msk.reshape(g2, m, bk)
    rank = jnp.cumsum(mg, axis=1) - mg
    vg = vq.reshape(g2, n, bk).astype(jnp.int32)
    dense = jnp.zeros((g2, m, bk), jnp.int32)
    for i in range(n):
        dense = dense + jnp.where((rank == i) & (mg == 1),
                                  vg[:, i][:, None, :], 0)
    return dense.reshape(n_rows, bk).astype(jnp.int8)


def _accumulate(x: jax.Array, q: jax.Array, s_blk: jax.Array,
                accum: str) -> jax.Array:
    """GEMM of (bm, R) x against (R, bk) int8 codes with (G, bk) scales:
    int-chain (bit-deterministic) or plain f32."""
    if accum == "int32":
        return _ref.int_group_matmul_ref(x, q, s_blk)
    sf = jnp.repeat(s_blk, q.shape[0] // s_blk.shape[0], axis=0)
    return jnp.dot(x.astype(jnp.float32), q.astype(jnp.float32) * sf,
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# sparse ws_ocs_matmul
# ---------------------------------------------------------------------------

def _col_kernel(x_ref, w_ref, s_ref, b_ref, xs_ref, o_ref, *, bits, n, m,
                n_rows, accum):
    q = _expand_col_block(w_ref[...], b_ref[...], bits=bits, n=n, m=m,
                          n_rows=n_rows)
    acc = _accumulate(x_ref[...], q, s_ref[...], accum)
    if xs_ref is not None:
        acc = acc * xs_ref[...].astype(jnp.float32)
    o_ref[...] = acc


def _row_kernel(idx_ref, x_ref, w_ref, s_ref, xs_ref, o_ref, *, bits, nc,
                accum):
    xc = jnp.take(x_ref[...], idx_ref[...], axis=1)     # kept columns only
    vq = _unpack_vals(w_ref[...], bits, nc)
    acc = _accumulate(xc, vq, s_ref[...], accum)
    if xs_ref is not None:
        acc = acc * xs_ref[...].astype(jnp.float32)
    o_ref[...] = acc


def sparse_ws_ocs_matmul(x: jax.Array, w_data: jax.Array,
                         w_scale: jax.Array, w_idx: jax.Array, *, n: int,
                         m: int, bits: int = 4,
                         x_scale: Optional[jax.Array] = None,
                         accum: str = "f32", bm: int = 128, bk: int = 128,
                         interpret: bool = False) -> jax.Array:
    """N:M-sparse panel-stationary matmul. x (M, N); w_data compressed
    (Nc//2, K) uint8 or (Nc, K) int8; w_scale (G, K); w_idx bitmask
    (N//8, K) [col] or kept rows (Nc,) [row]. Output (M, K) f32."""
    M, N = x.shape
    K = w_data.shape[1]
    Ncp = w_data.shape[0]
    Nc = N * n // m
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("sparse_ws_ocs_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)
    grid = (K // bk, M // bm)
    out_shape = jax.ShapeDtypeStruct((M, K), jnp.float32)
    cp = pltpu.CompilerParams(dimension_semantics=("arbitrary", "arbitrary"))

    if w_idx.ndim == 1:  # row granularity: scalar-prefetched kept rows
        in_specs = [
            pl.BlockSpec((bm, N), lambda k, m_, idx: (m_, 0)),
            pl.BlockSpec((Ncp, bk), lambda k, m_, idx: (0, k)),
            pl.BlockSpec((G, bk), lambda k, m_, idx: (0, k)),
        ]
        args = [x, w_data, w_scale]
        kern = functools.partial(_row_kernel, bits=bits, nc=Nc, accum=accum)
        if x_scale is not None:
            in_specs.append(pl.BlockSpec((bm, 1),
                                         lambda k, m_, idx: (m_, 0)))
            args.append(x_scale)
            wrapped = kern
        else:
            wrapped = lambda ir, xr, wr, sr, orf: \
                kern(ir, xr, wr, sr, None, orf)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bk), lambda k, m_, idx: (m_, k)))
        return pl.pallas_call(wrapped, grid_spec=grid_spec,
                              out_shape=out_shape, compiler_params=cp,
                              interpret=interpret)(w_idx, *args)

    in_specs = [
        pl.BlockSpec((bm, N), lambda k, m_: (m_, 0)),
        pl.BlockSpec((Ncp, bk), lambda k, m_: (0, k)),    # compressed panel
        pl.BlockSpec((G, bk), lambda k, m_: (0, k)),
        pl.BlockSpec((N // 8, bk), lambda k, m_: (0, k)),  # bitmask panel
    ]
    args = [x, w_data, w_scale, w_idx]
    kern = functools.partial(_col_kernel, bits=bits, n=n, m=m, n_rows=N,
                             accum=accum)
    if x_scale is not None:
        in_specs.append(pl.BlockSpec((bm, 1), lambda k, m_: (m_, 0)))
        args.append(x_scale)
        wrapped = kern
    else:
        wrapped = lambda xr, wr, sr, br, orf: \
            kern(xr, wr, sr, br, None, orf)
    return pl.pallas_call(wrapped, grid=grid, in_specs=in_specs,
                          out_specs=pl.BlockSpec((bm, bk),
                                                 lambda k, m_: (m_, k)),
                          out_shape=out_shape, compiler_params=cp,
                          interpret=interpret)(*args)


# ---------------------------------------------------------------------------
# sparse fused_matmul: compressed weights through the fused
# prologue/epilogue pipeline (group-RMSNorm → GEMM → act/GLU → bias →
# residual → int8 requant), same stage order as the dense kernel
# ---------------------------------------------------------------------------

def _sparse_fused_kernel(refs, *, bits, n, m, n_rows, act, has, norm_group,
                         norm_eps, accum):
    """refs: row granularity prepends the scalar-prefetched index
    vector(s); then [x, w, s] (+bitmask for col) + optional
    [gamma, x_scale, (w2, s2 [, mask2]), bias, residual, out_scale] +
    [out]."""
    nc = n_rows * n // m
    it = iter(refs)
    idx_ref = next(it) if has["row"] else None
    idx2_ref = next(it) if has["row"] and has["glu"] else None
    x_ref, w_ref, s_ref = next(it), next(it), next(it)
    b1_ref = None if has["row"] else next(it)
    g_ref = next(it) if has["gamma"] else None
    xs_ref = next(it) if has["x_scale"] else None
    w2_ref = next(it) if has["glu"] else None
    s2_ref = next(it) if has["glu"] else None
    b2m_ref = next(it) if has["glu"] and not has["row"] else None
    b_ref = next(it) if has["bias"] else None
    r_ref = next(it) if has["residual"] else None
    q_ref = next(it) if has["requant"] else None
    o_ref = next(it)

    x = x_ref[...]
    if g_ref is not None:
        xf = x.astype(jnp.float32)
        bm_, n_ = xf.shape
        xg = xf.reshape(bm_, n_ // norm_group, norm_group)
        partial_ms = jnp.mean(jnp.square(xg), axis=-1)
        global_ms = jnp.mean(partial_ms, axis=-1, keepdims=True)
        x = xf * jax.lax.rsqrt(global_ms + norm_eps) \
            * g_ref[...].astype(jnp.float32)

    def gemm(w_r, s_r, mask_r, i_r):
        if has["row"]:
            xc = jnp.take(x, i_r[...], axis=1)
            return _accumulate(xc, _unpack_vals(w_r[...], bits, nc),
                               s_r[...], accum)
        q = _expand_col_block(w_r[...], mask_r[...], bits=bits, n=n, m=m,
                              n_rows=n_rows)
        return _accumulate(x, q, s_r[...], accum)

    acc = gemm(w_ref, s_ref, b1_ref, idx_ref)
    if xs_ref is not None:
        acc = acc * xs_ref[...].astype(jnp.float32)

    if w2_ref is not None:
        acc2 = gemm(w2_ref, s2_ref, b2m_ref, idx2_ref)
        if xs_ref is not None:
            acc2 = acc2 * xs_ref[...].astype(jnp.float32)
        acc = _apply_act(acc, act) * acc2
    else:
        acc = _apply_act(acc, act)

    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    if r_ref is not None:
        acc = acc + r_ref[...].astype(jnp.float32)
    if q_ref is not None:
        q = jnp.round(acc / q_ref[...].astype(jnp.float32))
        o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
    else:
        o_ref[...] = acc


def sparse_fused_matmul(x: jax.Array, w_data: jax.Array,
                        w_scale: jax.Array, w_idx: jax.Array, *, n: int,
                        m: int, bits: int = 4,
                        gamma: Optional[jax.Array] = None,
                        norm_group: int = 128, norm_eps: float = 1e-6,
                        x_scale: Optional[jax.Array] = None,
                        act: str = "none",
                        w2_data: Optional[jax.Array] = None,
                        w2_scale: Optional[jax.Array] = None,
                        w2_idx: Optional[jax.Array] = None,
                        bias: Optional[jax.Array] = None,
                        residual: Optional[jax.Array] = None,
                        out_scale: Optional[jax.Array] = None,
                        accum: str = "f32", bm: int = 128, bk: int = 128,
                        interpret: bool = False) -> jax.Array:
    """Fused-epilogue WS-OCS matmul on N:M-compressed weights. Same
    optional stages as ``fused_matmul``; the GLU gate weight must carry
    the same (n, m, granularity) sparsity as the main weight."""
    M, N = x.shape
    K = w_data.shape[1]
    Ncp = w_data.shape[0]
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("sparse_fused_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)
    if gamma is not None:
        norm_group = min(norm_group, N)
        assert N % norm_group == 0, (N, norm_group)
        if accum == "int32":
            raise ValueError("int-accumulation mode has no norm prologue")
    row = w_idx.ndim == 1
    if w2_data is not None:
        assert w2_data.shape == w_data.shape, (w2_data.shape, w_data.shape)
        assert w2_scale is not None and w2_idx is not None
        assert w2_idx.ndim == w_idx.ndim, (w2_idx.shape, w_idx.shape)

    has = {"row": row, "gamma": gamma is not None,
           "x_scale": x_scale is not None, "glu": w2_data is not None,
           "bias": bias is not None, "residual": residual is not None,
           "requant": out_scale is not None}

    def spec(shape, imap):
        # row granularity index maps take the trailing scalar-ref args
        if row:
            nsc = 2 if has["glu"] else 1
            return pl.BlockSpec(shape, lambda k, m_, *sc: imap(k, m_))
        return pl.BlockSpec(shape, imap)

    in_specs = [
        spec((bm, N), lambda k, m_: (m_, 0)),
        spec((Ncp, bk), lambda k, m_: (0, k)),            # compressed panel
        spec((G, bk), lambda k, m_: (0, k)),
    ]
    args = [x, w_data, w_scale]
    if not row:
        in_specs.append(spec((N // 8, bk), lambda k, m_: (0, k)))
        args.append(w_idx)
    if has["gamma"]:
        in_specs.append(spec((1, N), lambda k, m_: (0, 0)))
        args.append(gamma.reshape(1, N))
    if has["x_scale"]:
        in_specs.append(spec((bm, 1), lambda k, m_: (m_, 0)))
        args.append(x_scale)
    if has["glu"]:
        in_specs.append(spec((Ncp, bk), lambda k, m_: (0, k)))
        in_specs.append(spec((G, bk), lambda k, m_: (0, k)))
        args.extend([w2_data, w2_scale])
        if not row:
            in_specs.append(spec((N // 8, bk), lambda k, m_: (0, k)))
            args.append(w2_idx)
    if has["bias"]:
        in_specs.append(spec((1, bk), lambda k, m_: (0, k)))
        args.append(bias.reshape(1, K))
    if has["residual"]:
        in_specs.append(spec((bm, bk), lambda k, m_: (m_, k)))
        args.append(residual)
    if has["requant"]:
        in_specs.append(spec((bm, 1), lambda k, m_: (m_, 0)))
        args.append(out_scale)

    out_dtype = jnp.int8 if has["requant"] else jnp.float32
    kern = functools.partial(_sparse_fused_kernel, bits=bits, n=n, m=m,
                             n_rows=N, act=act, has=has,
                             norm_group=norm_group, norm_eps=norm_eps,
                             accum=accum)
    cp = pltpu.CompilerParams(dimension_semantics=("arbitrary", "arbitrary"))
    grid = (K // bk, M // bm)
    out_shape = jax.ShapeDtypeStruct((M, K), out_dtype)
    if row:
        scalars = [w_idx] + ([w2_idx] if has["glu"] else [])
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(scalars), grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bk), lambda k, m_, *sc: (m_, k)))
        return pl.pallas_call(lambda *refs: kern(refs),
                              grid_spec=grid_spec, out_shape=out_shape,
                              compiler_params=cp,
                              interpret=interpret)(*scalars, *args)
    return pl.pallas_call(lambda *refs: kern(refs), grid=grid,
                          in_specs=in_specs,
                          out_specs=pl.BlockSpec((bm, bk),
                                                 lambda k, m_: (m_, k)),
                          out_shape=out_shape, compiler_params=cp,
                          interpret=interpret)(*args)


# ---------------------------------------------------------------------------
# sparse rcw_matmul: the explicit double-buffered weight stream moves the
# COMPRESSED (Ncp × bk) panel — per-panel DMA bytes shrink by ~n/m (plus
# the bitmask for col), i.e. the paper's weight-update latency scales
# with the sparsity factor. Phase-1/Phase-2 overlap is unchanged.
# ---------------------------------------------------------------------------

def _sparse_rcw_kernel(refs, *, bits, n, m, n_rows, bk, rcw, row):
    if row:
        idx_ref, w_hbm, x_ref, s_ref, o_ref, wbuf, sems = refs
        b_ref = None
    else:
        idx_ref = None
        w_hbm, x_ref, s_ref, b_ref, o_ref, wbuf, sems = refs
    k, m_ = pl.program_id(0), pl.program_id(1)
    nk = pl.num_programs(0)

    def panel_copy(ki, slot):
        return pltpu.make_async_copy(
            w_hbm.at[:, pl.ds(ki * bk, bk)], wbuf.at[slot], sems.at[slot])

    if rcw:
        @pl.when((k == 0) & (m_ == 0))
        def _():
            cp = panel_copy(0, 0)
            cp.start()
            cp.wait()

        @pl.when((m_ == 0) & (k + 1 < nk))
        def _():
            panel_copy(k + 1, (k + 1) % 2).start()

        @pl.when((m_ == 0) & (k > 0))
        def _():
            panel_copy(k, k % 2).wait()
    else:
        @pl.when(m_ == 0)
        def _():
            cp = panel_copy(k, k % 2)
            cp.start()
            cp.wait()

    nc = n_rows * n // m
    if row:
        xc = jnp.take(x_ref[...], idx_ref[...], axis=1)
        vq = _unpack_vals(wbuf[k % 2], bits, nc)
        o_ref[...] = _accumulate(xc, vq, s_ref[...], "f32")
    else:
        q = _expand_col_block(wbuf[k % 2], b_ref[...], bits=bits, n=n,
                              m=m, n_rows=n_rows)
        o_ref[...] = _accumulate(x_ref[...], q, s_ref[...], "f32")


def sparse_rcw_matmul(x: jax.Array, w_data: jax.Array, w_scale: jax.Array,
                      w_idx: jax.Array, *, n: int, m: int, bits: int = 4,
                      bm: int = 128, bk: int = 128, rcw: bool = True,
                      interpret: bool = False) -> jax.Array:
    """Explicit-RCW sparse variant: compressed weights stay in HBM and
    the kernel double-buffers (Ncp × bk) panels — the weight stream is
    n/m the dense size. f32 accumulation (serving path)."""
    M, N = x.shape
    K = w_data.shape[1]
    Ncp = w_data.shape[0]
    G = w_scale.shape[0]
    req_bm, req_bk = bm, bk
    bm = min(bm, M)
    bk = min(bk, K)
    check_tileable("sparse_rcw_matmul", x.shape, w_data.shape,
                   M, bm, req_bm, K, bk, req_bk)
    grid = (K // bk, M // bm)
    row = w_idx.ndim == 1
    kern = functools.partial(_sparse_rcw_kernel, bits=bits, n=n, m=m,
                             n_rows=N, bk=bk, rcw=rcw, row=row)
    cp = pltpu.CompilerParams(dimension_semantics=("arbitrary", "arbitrary"))
    out_shape = jax.ShapeDtypeStruct((M, K), jnp.float32)
    scratch = [pltpu.VMEM((2, Ncp, bk), w_data.dtype),
               pltpu.SemaphoreType.DMA((2,))]
    if row:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY),
                pl.BlockSpec((bm, N), lambda k, m_, idx: (m_, 0)),
                pl.BlockSpec((G, bk), lambda k, m_, idx: (0, k)),
            ],
            out_specs=pl.BlockSpec((bm, bk), lambda k, m_, idx: (m_, k)),
            scratch_shapes=scratch)
        return pl.pallas_call(lambda *refs: kern(refs),
                              grid_spec=grid_spec, out_shape=out_shape,
                              compiler_params=cp,
                              interpret=interpret)(w_idx, w_data, x, w_scale)
    return pl.pallas_call(
        lambda *refs: kern(refs),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY),
            pl.BlockSpec((bm, N), lambda k, m_: (m_, 0)),
            pl.BlockSpec((G, bk), lambda k, m_: (0, k)),
            pl.BlockSpec((N // 8, bk), lambda k, m_: (0, k)),  # bitmask
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda k, m_: (m_, k)),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=cp,
        interpret=interpret,
    )(w_data, x, w_scale, w_idx)
