"""Single-dispatch fused attention decode kernel (DESIGN.md §7).

The unfused decode path issues three dispatches per layer — QK^T einsum,
group-softmax (paper eq 1), PV einsum — bouncing an fp32 (B, H, S) logits
tensor and an equally large probs tensor through HBM. This kernel folds
all three: scores, the LUT-exp group-softmax partial accumulation, and
the PV accumulation happen on the same VMEM-resident KV tiles, and only
the (G, D) output leaves the kernel.

Group-softmax algebra (not plain online softmax): the paper normalizes
with per-*group* maxima merged late, and with the piecewise-linear LUT
exp the usual flash-style running rescale (``lut(a)·lut(b) ≠ lut(a+b)``)
would drift from the unfused reference. The kernel therefore runs two
sweeps over the KV blocks of each (batch, kv-head):

  phase 0   scores only → the exact global max of the group maxima
  phase 1   per-group max → LUT-exp → per-group sums, each group scaled
            by ``exp(m_g − m_global)`` exactly as eq (1) prescribes, and
            the PV partial products accumulated in VMEM scratch

so the result matches ``ref.attention_decode_ref`` (einsum →
group_softmax → einsum) to fp32 round-off in both LUT and exact-exp
modes. KV is read twice — the split-K trade every flash-decoding kernel
makes — while the O(S) logits/probs HBM round-trips disappear.

Layouts: q (B, Hkv, G, D) grouped queries; k/v stay in the cache layout
(B, S, Hkv, D) — the BlockSpec index map does the GQA head sharing and
the (b, s, h, d) → tile mapping, so no transpose/copy is dispatched.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fusion import LUT_HI, LUT_LO, LUT_SEGMENTS, build_exp_lut
from repro.kernels import pallas_compat as pltpu
from repro.kernels.group_softmax import _lut_exp_block

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, ab_ref, o_ref,
            mrun_ref, den_ref, acc_ref, *,
            scale, group, use_lut, window, bs, gq):
    ph, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((ph == 0) & (ki == 0))
    def _():
        mrun_ref[...] = jnp.full_like(mrun_ref, _NEG)
        den_ref[...] = jnp.zeros_like(den_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bs, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = ki * bs + jax.lax.broadcasted_iota(jnp.int32, (gq, bs), 1)
    mask = kpos < length
    if window is not None:
        mask = jnp.logical_and(mask, kpos > length - 1 - window)
    s = jnp.where(mask, s, _NEG)
    nb = bs // group
    sg = s.reshape(gq, nb, group)
    m_g = jnp.max(sg, axis=-1)                              # (G, nb)

    @pl.when(ph == 0)
    def _():
        m_blk = jnp.max(m_g, axis=-1, keepdims=True)        # (G, 1)
        mrun_ref[...] = jnp.maximum(mrun_ref[...],
                                    jnp.broadcast_to(m_blk, mrun_ref.shape))

    @pl.when(ph == 1)
    def _():
        m = mrun_ref[:, :1]                                 # exact global max
        if use_lut:
            p = _lut_exp_block(sg - m_g[..., None], ab_ref, LUT_LO, LUT_HI)
            r = _lut_exp_block(m_g - m, ab_ref, LUT_LO, LUT_HI)
        else:
            p = jnp.exp(sg - m_g[..., None])
            r = jnp.exp(m_g - m)
        s_g = jnp.sum(p, axis=-1)                           # (G, nb)
        den = jnp.sum(s_g * r, axis=-1, keepdims=True)
        den_ref[...] = den_ref[...] + jnp.broadcast_to(den, den_ref.shape)
        pr = (p * r[..., None]).reshape(gq, bs)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bs, D)
        acc_ref[...] = acc_ref[...] + jnp.dot(
            pr, v, preferred_element_type=jnp.float32)

    @pl.when((ph == 1) & (ki == nk - 1))
    def _():
        den = jnp.maximum(den_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / den).astype(o_ref.dtype)


def attention_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, group_size: int = 64,
                     use_lut: bool = True, scale: Optional[float] = None,
                     window: Optional[int] = None, block_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """q (B, H, D) single decode query; k/v (B, S, Hkv, D) cache layout;
    lengths (B,) or (B, 1) int32 valid prefix lengths. Returns (B, H, D).
    S must be divisible by the KV block, the block by ``group_size``."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    g = min(group_size, S)
    assert S % g == 0, (S, g)
    bs = max(min(block_k, S) // g * g, g)     # block = whole #groups...
    while S % bs:
        bs -= g                               # ...and a divisor of S
    assert S % bs == 0 and bs % g == 0, (S, bs, g)
    scale = scale if scale is not None else D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    len2 = lengths.reshape(B, 1).astype(jnp.int32)
    a, b = build_exp_lut()
    ab = jnp.stack([a, b], axis=1)

    kern = functools.partial(_kernel, scale=scale, group=g, use_lut=use_lut,
                             window=window, bs=bs, gq=G)
    out = pl.pallas_call(
        kern,
        grid=(B * Hkv, 2, S // bs),           # (bh, phase, kv-block)
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda bh, ph, ki: (bh // Hkv, bh % Hkv, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda bh, ph, ki: (bh // Hkv, ki, bh % Hkv, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda bh, ph, ki: (bh // Hkv, ki, bh % Hkv, 0)),
            pl.BlockSpec((1, 1), lambda bh, ph, ki: (bh // Hkv, 0)),
            pl.BlockSpec((LUT_SEGMENTS, 2), lambda bh, ph, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda bh, ph, ki: (bh // Hkv, bh % Hkv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((G, 128), jnp.float32),   # denominator
            pltpu.VMEM((G, D), jnp.float32),     # PV accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, len2, ab)
    return out.reshape(B, H, D)
