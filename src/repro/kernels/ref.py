"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` matches its kernel's semantics exactly (same LUT algebra,
same accumulation dtype) so tests can ``assert_allclose`` across shape /
dtype sweeps. These are also the lowering path used on non-TPU backends
(see ``ops.py``), so they are written to fuse well under XLA.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fusion
from repro.core.quant import SparsityConfig, expand_nm, unpack_int4


def dequant_weight_ref(w_data: jax.Array, w_scale: jax.Array, bits: int,
                       out_dtype=jnp.float32) -> jax.Array:
    """(N, K) float weight from packed int4 (N//2, K) or int8 (N, K) data
    with (G, K) group scales."""
    q = unpack_int4(w_data, axis=0) if bits == 4 else w_data
    n = q.shape[0]
    g = w_scale.shape[0]
    sf = jnp.repeat(w_scale, n // g, axis=0)
    return (q.astype(jnp.float32) * sf).astype(out_dtype)


def ws_ocs_matmul_ref(x: jax.Array, w_data: jax.Array, w_scale: jax.Array,
                      bits: int = 4, x_scale: Optional[jax.Array] = None,
                      out_dtype=jnp.float32) -> jax.Array:
    """out[M,K] = dequant(x) @ dequant(w). ``x`` may be int8 (with
    per-row ``x_scale`` (M,1)) or float."""
    w = dequant_weight_ref(w_data, w_scale, bits)
    xf = x.astype(jnp.float32)
    out = jnp.dot(xf, w, preferred_element_type=jnp.float32)
    if x_scale is not None:
        out = out * x_scale.astype(jnp.float32)
    return out.astype(out_dtype)


def fused_matmul_ref(x: jax.Array, w_data: jax.Array, w_scale: jax.Array, *,
                     bits: int = 4, gamma: Optional[jax.Array] = None,
                     norm_group: int = 128, norm_eps: float = 1e-6,
                     x_scale: Optional[jax.Array] = None, act: str = "none",
                     w2_data: Optional[jax.Array] = None,
                     w2_scale: Optional[jax.Array] = None,
                     bias: Optional[jax.Array] = None,
                     residual: Optional[jax.Array] = None,
                     out_scale: Optional[jax.Array] = None) -> jax.Array:
    """Unfused composition of the fused-epilogue WS-OCS kernel: the same
    stages (group-RMSNorm prologue → GEMM → act/GLU → bias → residual →
    int8 requant) as separate jnp ops, in the kernel's f32 algebra."""
    xf = x.astype(jnp.float32)
    if gamma is not None:
        g = min(norm_group, xf.shape[-1])
        xf = fusion.group_rmsnorm(xf, gamma.astype(jnp.float32),
                                  group_size=g, eps=norm_eps)
    acc = jnp.dot(xf, dequant_weight_ref(w_data, w_scale, bits),
                  preferred_element_type=jnp.float32)
    if x_scale is not None:
        acc = acc * x_scale.astype(jnp.float32)
    if act == "silu":
        acted = jax.nn.silu(acc)
    elif act == "gelu":
        acted = jax.nn.gelu(acc)
    elif act == "none":
        acted = acc
    else:  # fail on both backends alike (kernel asserts the same set)
        raise ValueError(f"unknown epilogue act {act!r}")
    if w2_data is not None:
        acc2 = jnp.dot(xf, dequant_weight_ref(w2_data, w2_scale, bits),
                       preferred_element_type=jnp.float32)
        if x_scale is not None:
            acc2 = acc2 * x_scale.astype(jnp.float32)
        acted = acted * acc2
    if bias is not None:
        acted = acted + bias.astype(jnp.float32)
    if residual is not None:
        acted = acted + residual.astype(jnp.float32)
    if out_scale is not None:
        q = jnp.round(acted / out_scale.astype(jnp.float32))
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    return acted


# --- structured N:M sparsity references (DESIGN.md §14) -------------------
#
# Granularity is recovered from the metadata tensor's rank: ndim == 2 →
# per-output-column bitmask (uint8 (N//8, K)); ndim == 1 → kept-row
# indices (int32 (Nc,), the flexible per-row N-of-M variant).


def _sparsity_cfg(w_idx: jax.Array, n: int, m: int) -> SparsityConfig:
    return SparsityConfig(n, m, "row" if w_idx.ndim == 1 else "col")


def int_group_matmul_ref(xq: jax.Array, q: jax.Array,
                         w_scale: jax.Array) -> jax.Array:
    """Bit-deterministic int-accumulation GEMM: one exact int32 dot per
    scale group, combined in a fixed group-ascending f32 chain. The
    sparse kernels run this same helper per output tile, so kernel and
    reference agree BIT-exactly for any tiling (integer dots are exactly
    associative; the f32 scale-combine order is pinned here). Compare
    jit-compiled programs on both sides: the CPU backend contracts the
    mul+add pair to an FMA at LLVM emission (below HLO, so even
    optimization_barrier can't split it), which makes eager evaluation
    differ from ANY compiled run by one rounding — but two compiled
    programs sharing this helper contract identically."""
    g = w_scale.shape[0]
    gs = q.shape[0] // g
    out = jnp.zeros((xq.shape[0], q.shape[1]), jnp.float32)
    for gi in range(g):
        part = jax.lax.dot(
            xq[:, gi * gs:(gi + 1) * gs].astype(jnp.int32),
            q[gi * gs:(gi + 1) * gs].astype(jnp.int32),
            preferred_element_type=jnp.int32)
        out = out + part.astype(jnp.float32) * w_scale[gi][None, :]
    return out


def sparse_expand_q_ref(w_data: jax.Array, w_idx: jax.Array, *, n: int,
                        m: int, bits: int, n_rows: int) -> jax.Array:
    """Dense int8 (N, K) codes (zeros in pruned slots) from compressed
    N:M storage — exact round-trip of ``quant.compact_nm``."""
    sp = _sparsity_cfg(w_idx, n, m)
    nc = n_rows * n // m
    vals = unpack_int4(w_data, axis=0, n=nc) if bits == 4 else w_data
    return expand_nm(vals, w_idx, sp, n_rows)


def sparse_ws_ocs_matmul_ref(x: jax.Array, w_data: jax.Array,
                             w_scale: jax.Array, w_idx: jax.Array, *,
                             n: int, m: int, bits: int = 4,
                             x_scale: Optional[jax.Array] = None,
                             accum: str = "f32",
                             out_dtype=jnp.float32) -> jax.Array:
    """Dense-mask reconstruction reference: expand the compressed weight
    back to its dense-masked equivalent and run the dense GEMM algebra.
    ``accum="int32"`` uses the bit-deterministic int chain (x must be
    int8); ``"f32"`` matches the dense kernels to fp32 round-off."""
    n_rows = x.shape[-1]
    q = sparse_expand_q_ref(w_data, w_idx, n=n, m=m, bits=bits,
                            n_rows=n_rows)
    if accum == "int32":
        out = int_group_matmul_ref(x, q, w_scale)
    else:
        g = w_scale.shape[0]
        wf = q.astype(jnp.float32) * jnp.repeat(w_scale, n_rows // g, axis=0)
        out = jnp.dot(x.astype(jnp.float32), wf,
                      preferred_element_type=jnp.float32)
    if x_scale is not None:
        out = out * x_scale.astype(jnp.float32)
    return out.astype(out_dtype)


def sparse_skip_matmul_ref(x: jax.Array, w_data: jax.Array,
                           w_scale: jax.Array, w_idx: jax.Array, *,
                           n: int, m: int, bits: int = 4,
                           x_scale: Optional[jax.Array] = None,
                           accum: str = "f32",
                           out_dtype=jnp.float32) -> jax.Array:
    """Row-granular compressed-skip lowering: gather the kept activation
    columns and contract only the Nc = N·n/m stored rows — ~m/n fewer
    MACs than the dense-mask path. Bit-exact vs the dense-mask reference
    in int-accumulation mode (dropped rows contribute exactly 0 to each
    int32 group partial); fp32 round-off otherwise."""
    assert w_idx.ndim == 1, "skip lowering needs row-granular sparsity"
    nc = w_idx.shape[0]
    vals = unpack_int4(w_data, axis=0, n=nc) if bits == 4 else w_data
    xc = jnp.take(x, w_idx, axis=-1)
    if accum == "int32":
        out = int_group_matmul_ref(xc, vals, w_scale)
    else:
        g = w_scale.shape[0]
        wf = vals.astype(jnp.float32) * jnp.repeat(w_scale, nc // g, axis=0)
        out = jnp.dot(xc.astype(jnp.float32), wf,
                      preferred_element_type=jnp.float32)
    if x_scale is not None:
        out = out * x_scale.astype(jnp.float32)
    return out.astype(out_dtype)


def sparse_fused_matmul_ref(x: jax.Array, w_data: jax.Array,
                            w_scale: jax.Array, w_idx: jax.Array, *,
                            n: int, m: int, bits: int = 4,
                            gamma: Optional[jax.Array] = None,
                            norm_group: int = 128, norm_eps: float = 1e-6,
                            x_scale: Optional[jax.Array] = None,
                            act: str = "none",
                            w2_data: Optional[jax.Array] = None,
                            w2_scale: Optional[jax.Array] = None,
                            w2_idx: Optional[jax.Array] = None,
                            bias: Optional[jax.Array] = None,
                            residual: Optional[jax.Array] = None,
                            out_scale: Optional[jax.Array] = None,
                            accum: str = "f32") -> jax.Array:
    """Fused-epilogue reference on compressed N:M weights: dense-mask
    reconstruction feeding the same stage algebra as
    :func:`fused_matmul_ref`. In ``accum="int32"`` mode (int8 x, no
    norm prologue) every GEMM runs the bit-deterministic int chain and
    all epilogue stages are elementwise f32, so the sparse kernel output
    is bit-identical to this reference for any tiling."""
    n_rows = x.shape[-1]

    def _gemm(xin, data, scale, idx):
        q = sparse_expand_q_ref(data, idx, n=n, m=m, bits=bits,
                                n_rows=n_rows)
        if accum == "int32":
            return int_group_matmul_ref(xin, q, scale)
        g = scale.shape[0]
        wf = q.astype(jnp.float32) * jnp.repeat(scale, n_rows // g, axis=0)
        return jnp.dot(xin.astype(jnp.float32), wf,
                       preferred_element_type=jnp.float32)

    if accum == "int32" and gamma is not None:
        raise ValueError("int-accumulation mode has no norm prologue")
    xf = x
    if gamma is not None:
        g = min(norm_group, x.shape[-1])
        xf = fusion.group_rmsnorm(x.astype(jnp.float32),
                                  gamma.astype(jnp.float32),
                                  group_size=g, eps=norm_eps)
    acc = _gemm(xf, w_data, w_scale, w_idx)
    if x_scale is not None:
        acc = acc * x_scale.astype(jnp.float32)
    if act == "silu":
        acted = jax.nn.silu(acc)
    elif act == "gelu":
        acted = jax.nn.gelu(acc)
    elif act == "none":
        acted = acc
    else:
        raise ValueError(f"unknown epilogue act {act!r}")
    if w2_data is not None:
        acc2 = _gemm(xf, w2_data, w2_scale, w2_idx)
        if x_scale is not None:
            acc2 = acc2 * x_scale.astype(jnp.float32)
        acted = acted * acc2
    if bias is not None:
        acted = acted + bias.astype(jnp.float32)
    if residual is not None:
        acted = acted + residual.astype(jnp.float32)
    if out_scale is not None:
        q = jnp.round(acted / out_scale.astype(jnp.float32))
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    return acted


def attention_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *, group_size: int = 64,
                         use_lut: bool = True,
                         scale: Optional[float] = None,
                         window: Optional[int] = None) -> jax.Array:
    """Unfused three-dispatch decode composition: QK^T einsum →
    group-softmax (eq 1) → PV einsum. q (B, H, D) single query; k/v
    (B, S, Hkv, D) cache layout; lengths (B,) or (B, 1) valid prefix
    lengths. Returns (B, H, D). This is the oracle the fused
    single-dispatch kernel (attention_decode.py) must reproduce."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    s_ = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * s_
    ln = lengths.reshape(B)[:, None, None, None]
    kpos = jnp.arange(S)[None, None, None, :]
    m = kpos < ln
    if window is not None:
        m = m & (kpos > ln - 1 - window)
    logits = jnp.where(m, logits, -1e30)
    probs = fusion.group_softmax(logits, group_size=group_size,
                                 use_lut=use_lut)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


def gather_paged_kv_ref(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Assemble the dense per-request KV view from a block pool.
    pool (NB, BS, Hkv, D); block_tables (B, NBMAX) int32 pool-block ids
    (0 = the reserved null block) → (B, NBMAX·BS, Hkv, D). Positions past
    a request's length read null/stale blocks — callers mask by length."""
    NB, BS = pool.shape[0], pool.shape[1]
    B, nbmax = block_tables.shape
    flat_idx = (block_tables.astype(jnp.int32)[:, :, None] * BS
                + jnp.arange(BS, dtype=jnp.int32)[None, None, :])
    flat_idx = flat_idx.reshape(B, nbmax * BS)
    return pool.reshape((NB * BS,) + pool.shape[2:])[flat_idx]


def paged_attention_decode_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array, *, group_size: int = 64,
                               use_lut: bool = True,
                               scale: Optional[float] = None,
                               window: Optional[int] = None) -> jax.Array:
    """Oracle for the paged fused decode kernel: gather the block pool
    through the table into the dense cache layout, then run the dense
    decode composition. With the virtual length NBMAX·BS equal to the
    dense max_len this is *bit-identical* to the dense decode path —
    invalid positions are masked to the same -1e30 before the softmax.
    The kernel caps its softmax group at the block size; pass the same
    effective group here when checking LUT-mode equivalence."""
    kg = gather_paged_kv_ref(k_pool, block_tables)
    vg = gather_paged_kv_ref(v_pool, block_tables)
    return attention_decode_ref(q, kg, vg, lengths, group_size=group_size,
                                use_lut=use_lut, scale=scale, window=window)


def paged_flash_prefill_ref(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            start: jax.Array, *,
                            window: Optional[int] = None,
                            use_lut: bool = False,
                            scale: Optional[float] = None) -> jax.Array:
    """Golden oracle for the paged flash-prefill kernel: gather the block
    pool through the table into the dense prefix layout, then run the
    exact materialized offset-causal oracle. This IS the PR 5 chunk path
    (gather + ``attention_ref(q_offset=)``), kept bit-identical so the
    Scheduler's off-TPU token-identity guarantee is unchanged. q
    (B, H, C, D); pools (NB, BS, Hkv, D); block_tables (B, NBMAX);
    start (B,) absolute chunk offsets. Returns (B, H, C, D)."""
    kg = jnp.swapaxes(gather_paged_kv_ref(k_pool, block_tables), 1, 2)
    vg = jnp.swapaxes(gather_paged_kv_ref(v_pool, block_tables), 1, 2)
    return attention_ref(q, kg, vg, causal=True, window=window,
                         use_lut=use_lut, scale=scale,
                         q_offset=start.reshape(q.shape[0]))


def paged_flash_prefill_scan_ref(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, block_tables: jax.Array,
                                 start: jax.Array, *,
                                 window: Optional[int] = None,
                                 use_lut: bool = False,
                                 scale: Optional[float] = None) -> jax.Array:
    """O(written-prefix) online-softmax lowering of the paged flash
    prefill (the off-TPU analogue of the Pallas kernel's dataflow,
    enabled by ``REPRO_OPT_PAGEDFLASH=1``): KV blocks are fetched through
    the table one (B, BS) tile at a time inside a dynamically-bounded
    loop — no dense (B, NBMAX·BS) prefix copy and no (C, NBMAX·BS)
    materialized logits ever exist — and the loop stops at the last block
    the offset-causal mask can reach, so chunk cost scales with the
    written prefix, not the virtual max_len. Matches the gather oracle to
    fp32 round-off (exact exp; LUT mode to LUT tolerance — the running
    rescale, DESIGN.md §11)."""
    from repro.core import fusion
    B, H, C, D = q.shape
    BS, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    nbmax = block_tables.shape[1]
    s_ = scale if scale is not None else D ** -0.5
    exp = fusion.lut_exp if use_lut else jnp.exp
    qg = (q.astype(jnp.float32) * s_).reshape(B, Hkv, G, C, D)
    st = start.reshape(B).astype(jnp.int32)
    qpos = st[:, None] + jnp.arange(C, dtype=jnp.int32)[None]       # (B, C)
    bt = block_tables.astype(jnp.int32)
    # last logical block any query row can see (newest query = newest key)
    nb_live = jnp.minimum(jnp.max((st + C + BS - 1) // BS), nbmax)

    def body(i, carry):
        m, l, acc = carry
        ids = bt[:, i]                                              # (B,)
        kb = jnp.moveaxis(k_pool[ids].astype(jnp.float32), 1, 2)
        vb = jnp.moveaxis(v_pool[ids].astype(jnp.float32), 1, 2)
        sc = jnp.einsum("bhgcd,bhsd->bhgcs", qg, kb,
                        preferred_element_type=jnp.float32)
        kpos = i * BS + jnp.arange(BS, dtype=jnp.int32)             # (BS,)
        mask = kpos[None, :] <= qpos[:, :, None]                    # (B,C,BS)
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, :, None] - window)
        mask = mask[:, None, None]                                  # bcast H,G
        sc = jnp.where(mask, sc, -1e30)
        m_blk = jnp.max(sc, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.where(mask, exp(sc - m_new), 0.0)
        corr = exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum("bhgcs,bhsd->bhgcd", p, vb,
                                          preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((B, Hkv, G, C, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, C, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, C, D), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nb_live, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, H, C, D).astype(q.dtype)


def group_softmax_ref(x: jax.Array, group_size: int = 64,
                      use_lut: bool = True) -> jax.Array:
    return fusion.group_softmax(x, group_size=group_size, use_lut=use_lut)


def group_rmsnorm_ref(x: jax.Array, gamma: jax.Array, group_size: int = 128,
                      eps: float = 1e-6) -> jax.Array:
    return fusion.group_rmsnorm(x, gamma, group_size=group_size, eps=eps)


def group_layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                        group_size: int = 128, eps: float = 1e-5) -> jax.Array:
    return fusion.group_layernorm(x, gamma, beta, group_size=group_size, eps=eps)


def flash_attention_scan_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                             causal: bool = True,
                             window: Optional[int] = None,
                             use_lut: bool = False,
                             scale: Optional[float] = None,
                             block_k: int = 1024) -> jax.Array:
    """O(S)-memory online-softmax attention with native GQA: KV heads are
    never repeated; q is grouped (B, Hkv, G, Sq, D) and KV consumed in
    blocks with running (m, l, acc) state. This is the non-TPU lowering
    path for long sequences (the memory-roofline fix in EXPERIMENTS.md
    §Perf) and mirrors the Pallas flash kernel's algebra."""
    from repro.core import fusion
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    s_ = scale if scale is not None else D ** -0.5
    exp = fusion.lut_exp if use_lut else jnp.exp
    qg = (q.astype(jnp.float32) * s_).reshape(B, Hkv, G, Sq, D)

    nblk = -(-Sk // block_k)
    padk = nblk * block_k - Sk
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, padk), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, padk), (0, 0)))
    kb = jnp.moveaxis(kf.reshape(B, Hkv, nblk, block_k, D), 2, 0)
    vb = jnp.moveaxis(vf.reshape(B, Hkv, nblk, block_k, D), 2, 0)
    starts = jnp.arange(nblk) * block_k
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, start = blk
        sc = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kblk)
        kpos = start + jnp.arange(block_k)[None, :]
        mask = kpos < Sk
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        sc = jnp.where(mask, sc, -1e30)
        m_blk = jnp.max(sc, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_blk)
        p = exp(sc - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum("bkgqc,bkcd->bkgqd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: Optional[int] = None,
                  use_lut: bool = False, scale: Optional[float] = None,
                  q_offset: Optional[jax.Array] = None) -> jax.Array:
    """Exact (materialized-scores) attention. q (B,H,Sq,D); k/v (B,Hkv,Sk,D)
    with Hkv | H (GQA). ``window``: local attention half-width (keys with
    qpos - kpos >= window masked). ``q_offset`` (B,) int32: absolute
    position of the first query row (chunked prefill over a longer cached
    prefix — queries at q_offset+i, keys at 0..Sk-1); default keeps the
    classic suffix alignment qpos = arange(Sq) + (Sk - Sq). With
    q_offset, causal masking alone bounds validity: the newest query IS
    the newest written key, so no separate kv_len mask is needed."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    kpos = jnp.arange(Sk)[None, :]
    if q_offset is not None:
        assert causal, "q_offset requires causal masking for validity"
        qpos = q_offset.reshape(B)[:, None, None] + jnp.arange(Sq)[:, None]
        kpos = kpos[None]                       # (B, Sq, Sk) broadcasting
        mask = jnp.ones((B, Sq, Sk), bool)
    else:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if q_offset is not None:
        mask = mask[:, None]                    # (B, 1, Sq, Sk)
    logits = jnp.where(mask, logits, -jnp.inf)
    if use_lut:
        m = jnp.max(logits, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = fusion.lut_exp(logits - m)
        p = jnp.where(mask, p, 0.0)
        probs = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        probs = jnp.where(mask, probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
