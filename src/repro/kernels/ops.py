"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

On TPU the real ``pallas_call`` lowers; on CPU/GPU the pure-jnp oracle
(``ref.py``) is used so the whole framework (models, trainer, serving,
dry-run) runs everywhere. ``REPRO_FORCE_PALLAS=1`` (or
``force_pallas(True)``) routes through the kernels in interpret mode —
how the kernel test-suite executes them on this CPU container.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import attention_decode as _ad
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention_decode as _pad
from repro.kernels import paged_flash_prefill as _pfp
from repro.kernels import selective_scan as _ss
from repro.kernels import group_rmsnorm as _gr
from repro.kernels import group_softmax as _gs
from repro.kernels import ref
from repro.kernels import ws_ocs_matmul as _mm

_FORCE: Optional[bool] = None


def force_pallas(on: Optional[bool]) -> None:
    """Override dispatch: True → pallas (interpret off-TPU), False → ref,
    None → auto (pallas iff on TPU)."""
    global _FORCE
    _FORCE = on


def _use_pallas() -> bool:
    if _FORCE is not None:
        return _FORCE
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------

def ws_ocs_matmul(x, w_data, w_scale, *, bits=4, x_scale=None,
                  bm=128, bk=128, rcw=True):
    """Quantized panel-stationary matmul (see ws_ocs_matmul.py)."""
    if _use_pallas():
        if rcw and x_scale is None:
            return _mm.rcw_matmul(x, w_data, w_scale, bits=bits, bm=bm,
                                  bk=bk, rcw=True, interpret=_interpret())
        out = _mm.ws_ocs_matmul(x, w_data, w_scale, bits=bits,
                                x_scale=x_scale, bm=bm, bk=bk,
                                interpret=_interpret())
        return out
    return ref.ws_ocs_matmul_ref(x, w_data, w_scale, bits=bits,
                                 x_scale=x_scale)


def _tile(dim: int, block: int) -> int:
    """Largest tile ≤ block that divides dim (falls back to the whole
    dim — fine for the serving/test sizes the fused path handles)."""
    b = min(block, dim)
    return b if dim % b == 0 else dim


def _sparse_skip() -> bool:
    """``REPRO_OPT_SPARSESKIP=1``: off-TPU, lower row-granular N:M
    matmuls to the compressed-skip reference (~m/n fewer MACs; matches
    the dense-mask path to fp32 round-off). Default OFF so a sparse
    checkpoint serves BIT-identically to its dense-masked equivalent
    (the Scheduler token-identity tests rely on this)."""
    from repro.parallel.flags import opt
    return opt("SPARSESKIP", default=False)


def sparse_ws_ocs_matmul(x, w_data, w_scale, w_idx, *, n, m, bits=4,
                         x_scale=None, accum="f32", bm=128, bk=128,
                         rcw=True):
    """N:M-sparse panel-stationary matmul (DESIGN.md §14): compressed
    values + bitmask (col, w_idx ndim 2) or scalar-prefetched kept-row
    indices (row, ndim 1). On TPU the sparse kernels stream the
    compressed (Nc × bk) panel; off-TPU the default lowering expands to
    the dense-masked equivalent (bit-identical serving), and
    ``REPRO_OPT_SPARSESKIP=1`` switches row-granular weights to the
    compressed-skip contraction."""
    if _use_pallas():
        from repro.kernels import sparse_matmul as _sm
        M, K = x.shape[0], w_data.shape[1]
        bm, bk = _tile(M, bm), _tile(K, bk)
        if rcw and x_scale is None and accum == "f32":
            return _sm.sparse_rcw_matmul(x, w_data, w_scale, w_idx, n=n,
                                         m=m, bits=bits, bm=bm, bk=bk,
                                         rcw=True, interpret=_interpret())
        return _sm.sparse_ws_ocs_matmul(x, w_data, w_scale, w_idx, n=n,
                                        m=m, bits=bits, x_scale=x_scale,
                                        accum=accum, bm=bm, bk=bk,
                                        interpret=_interpret())
    if w_idx.ndim == 1 and _sparse_skip():
        return ref.sparse_skip_matmul_ref(x, w_data, w_scale, w_idx, n=n,
                                          m=m, bits=bits, x_scale=x_scale,
                                          accum=accum)
    return ref.sparse_ws_ocs_matmul_ref(x, w_data, w_scale, w_idx, n=n,
                                        m=m, bits=bits, x_scale=x_scale,
                                        accum=accum)


def sparse_fused_matmul(x, w_data, w_scale, w_idx, *, n, m, bits=4,
                        gamma=None, norm_group=128, norm_eps=1e-6,
                        x_scale=None, act="none", w2_data=None,
                        w2_scale=None, w2_idx=None, bias=None,
                        residual=None, out_scale=None, accum="f32",
                        bm=128, bk=128):
    """Fused prologue/epilogue WS-OCS matmul on N:M-compressed weights
    (DESIGN.md §14): same stage chain as ``fused_matmul``. Off-TPU the
    lowering is always the dense-mask reconstruction reference — bit-
    identical to the dense-masked checkpoint, so the fused decode path
    stays token-identical regardless of REPRO_OPT_SPARSESKIP."""
    kw = dict(n=n, m=m, bits=bits, gamma=gamma, norm_group=norm_group,
              norm_eps=norm_eps, x_scale=x_scale, act=act,
              w2_data=w2_data, w2_scale=w2_scale, w2_idx=w2_idx,
              bias=bias, residual=residual, out_scale=out_scale,
              accum=accum)
    if _use_pallas():
        from repro.kernels import sparse_matmul as _sm
        M, K = x.shape[0], w_data.shape[1]
        return _sm.sparse_fused_matmul(x, w_data, w_scale, w_idx,
                                       bm=_tile(M, bm), bk=_tile(K, bk),
                                       interpret=_interpret(), **kw)
    return ref.sparse_fused_matmul_ref(x, w_data, w_scale, w_idx, **kw)


def fused_matmul(x, w_data, w_scale, *, bits=4, gamma=None, norm_group=128,
                 norm_eps=1e-6, x_scale=None, act="none", w2_data=None,
                 w2_scale=None, bias=None, residual=None, out_scale=None,
                 bm=128, bk=128):
    """Fused prologue/epilogue WS-OCS matmul (DESIGN.md §7): one dispatch
    for group-RMSNorm → GEMM → act/GLU → bias → residual → requant."""
    kw = dict(bits=bits, gamma=gamma, norm_group=norm_group,
              norm_eps=norm_eps, x_scale=x_scale, act=act, w2_data=w2_data,
              w2_scale=w2_scale, bias=bias, residual=residual,
              out_scale=out_scale)
    if _use_pallas():
        M, K = x.shape[0], w_data.shape[1]
        return _mm.fused_matmul(x, w_data, w_scale, bm=_tile(M, bm),
                                bk=_tile(K, bk), interpret=_interpret(),
                                **kw)
    return ref.fused_matmul_ref(x, w_data, w_scale, **kw)


def attention_decode(q, k, v, lengths, *, group_size=64, use_lut=True,
                     scale=None, window=None, block_k=128):
    """Single-dispatch fused decode attention (QK^T + group-softmax + PV
    in one kernel); falls back to the three-dispatch ref composition."""
    S = k.shape[1]
    if _use_pallas() and S % min(group_size, S) == 0:
        return _ad.attention_decode(q, k, v, lengths,
                                    group_size=group_size, use_lut=use_lut,
                                    scale=scale, window=window,
                                    block_k=block_k, interpret=_interpret())
    return ref.attention_decode_ref(q, k, v, lengths, group_size=group_size,
                                    use_lut=use_lut, scale=scale,
                                    window=window)


def paged_attention_decode(q, k_pool, v_pool, block_tables, lengths, *,
                           group_size=64, use_lut=True, scale=None,
                           window=None):
    """Fused decode attention over a paged KV pool (DESIGN.md §10):
    k_pool/v_pool (NB, BS, Hkv, D), block_tables (B, NBMAX). The Pallas
    kernel gathers blocks through a scalar-prefetched table and caps the
    softmax group at the block size BS; the ref path gathers to the
    dense layout first and keeps the requested group, making it
    bit-identical to the dense decode composition (serving equivalence
    tests rely on this)."""
    BS = k_pool.shape[1]
    if _use_pallas() and BS % min(group_size, BS) == 0:
        # §13: under a multi-device mesh the opaque pallas_call would be
        # replicated by GSPMD (gathering the sharded pool); run it under
        # shard_map with heads split instead — bit-identical per head
        from repro.parallel import shard_kernels as sk
        routed = sk.route_mesh(q.shape[1], k_pool.shape[2])
        if routed is not None:
            return sk.sharded_paged_attention_decode(
                *routed, q, k_pool, v_pool, block_tables, lengths,
                group_size=group_size, use_lut=use_lut, scale=scale,
                window=window)
        return _pad.paged_attention_decode(
            q, k_pool, v_pool, block_tables, lengths,
            group_size=min(group_size, BS), use_lut=use_lut, scale=scale,
            window=window, interpret=_interpret())
    return ref.paged_attention_decode_ref(
        q, k_pool, v_pool, block_tables, lengths, group_size=group_size,
        use_lut=use_lut, scale=scale, window=window)


def group_softmax(x, group_size=64, use_lut=True):
    if _use_pallas() and use_lut and x.shape[-1] % min(group_size, x.shape[-1]) == 0:
        rows = 1
        for d in x.shape[:-1]:
            rows *= d
        br = 8 if rows % 8 == 0 else 1
        return _gs.group_softmax(x, group_size=group_size, block_rows=br,
                                 interpret=_interpret())
    return ref.group_softmax_ref(x, group_size=group_size, use_lut=use_lut)


def group_rmsnorm(x, gamma, group_size=128, eps=1e-6):
    if _use_pallas():
        rows = 1
        for d in x.shape[:-1]:
            rows *= d
        br = 8 if rows % 8 == 0 else 1
        return _gr.group_rmsnorm(x, gamma, group_size=group_size, eps=eps,
                                 block_rows=br, interpret=_interpret())
    return ref.group_rmsnorm_ref(x, gamma, group_size=group_size, eps=eps)


def group_layernorm(x, gamma, beta, group_size=128, eps=1e-5):
    if _use_pallas():
        rows = 1
        for d in x.shape[:-1]:
            rows *= d
        br = 8 if rows % 8 == 0 else 1
        return _gr.group_layernorm(x, gamma, beta, group_size=group_size,
                                   eps=eps, block_rows=br,
                                   interpret=_interpret())
    return ref.group_layernorm_ref(x, gamma, beta, group_size=group_size,
                                   eps=eps)


def _chunk_oracle() -> bool:
    """``REPRO_CHUNK_ORACLE=1``: rollback switch pinning every chunked-
    prefill attention to the PR 5 materialized gather oracle (also what
    the BENCH_pr6 dispatch rows trace as the ``dense-oracle`` arm)."""
    return os.environ.get("REPRO_CHUNK_ORACLE") == "1"


def attention(q, k, v, *, causal=True, window=None, use_lut=False,
              scale=None, block_q=128, block_k=128, q_offset=None):
    """Multi-head attention; flash kernel on TPU; off-TPU: the O(S)-memory
    flash-scan oracle for long sequences (REPRO_OPT_FLASH=1 — the §Perf
    memory-term optimization), else the exact materialized oracle.
    ``q_offset`` (B,): chunked-prefill alignment — queries start at an
    absolute per-batch offset over a longer written prefix. On the kernel
    path this lowers to the offset-causal flash kernel (DESIGN.md §11)
    honoring ``block_q``/``block_k``, and shapes the grid cannot tile
    RAISE rather than silently densifying; off-TPU it stays the exact
    oracle (bit-identical to PR 5 serving)."""
    Sq, Sk = q.shape[2], k.shape[2]
    if q_offset is not None:
        assert causal, "q_offset requires causal masking for validity"
        if _use_pallas() and not _chunk_oracle():
            bq, bk = min(block_q, Sq), min(block_k, Sk)
            if Sq % bq != 0 or Sk % bk != 0:
                raise ValueError(
                    f"attention(q_offset=): grid cannot tile q "
                    f"{tuple(q.shape)} / k {tuple(k.shape)} — chose "
                    f"block_q={bq} (requested {block_q}) for Sq={Sq}, "
                    f"block_k={bk} (requested {block_k}) for Sk={Sk}, "
                    f"but Sq % block_q == {Sq % bq} and Sk % block_k == "
                    f"{Sk % bk}; pad the chunk or pass dividing block "
                    "sizes (the hot loop must not densify)")
            return _fa.flash_attention(q, k, v, causal=True, window=window,
                                       use_lut=use_lut, scale=scale,
                                       block_q=block_q, block_k=block_k,
                                       q_offset=q_offset,
                                       interpret=_interpret())
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 use_lut=use_lut, scale=scale,
                                 q_offset=q_offset)
    if _use_pallas() and Sq % min(block_q, Sq) == 0 \
            and Sk % min(block_k, Sk) == 0:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   use_lut=use_lut, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=_interpret())
    from repro.parallel.flags import opt
    if opt("FLASH") and Sk >= 2048:
        return ref.flash_attention_scan_ref(
            q, k, v, causal=causal, window=window, use_lut=use_lut,
            scale=scale)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             use_lut=use_lut, scale=scale)


def paged_flash_prefill(q, k_pool, v_pool, block_tables, start, *,
                        window=None, use_lut=False, scale=None,
                        block_q=128):
    """Chunked-prefill attention directly over the paged KV pool
    (DESIGN.md §11): q (B, H, C, D) chunk queries at absolute positions
    ``start``..start+C-1; pools (NB, BS, Hkv, D); block_tables (B, NBMAX).
    On TPU the Pallas kernel streams KV tiles through scalar-prefetched
    block-table gathers — no dense prefix copy; untileable chunks RAISE.
    Off-TPU the default lowering is the gather + materialized-oracle
    composition (bit-identical to the PR 5 chunk path — the Scheduler's
    token-identity tests rely on this); ``REPRO_OPT_PAGEDFLASH=1``
    switches it to the O(written-prefix) online-softmax scan that never
    densifies the prefix (matches to fp32 round-off)."""
    C = q.shape[2]
    if _use_pallas() and not _chunk_oracle():
        bq = min(block_q, C)
        if C % bq != 0:
            raise ValueError(
                f"paged_flash_prefill: grid cannot tile q "
                f"{tuple(q.shape)} over pools {tuple(k_pool.shape)} — "
                f"chose block_q={bq} (requested {block_q}) for chunk "
                f"C={C}, but C % block_q == {C % bq}; pad the chunk "
                "(the hot loop must not densify)")
        # §13: same shard_map head split as paged_attention_decode
        from repro.parallel import shard_kernels as sk
        routed = sk.route_mesh(q.shape[1], k_pool.shape[2])
        if routed is not None:
            return sk.sharded_paged_flash_prefill(
                *routed, q, k_pool, v_pool, block_tables, start,
                window=window, use_lut=use_lut, scale=scale,
                block_q=block_q)
        return _pfp.paged_flash_prefill(
            q, k_pool, v_pool, block_tables, start, window=window,
            use_lut=use_lut, scale=scale, block_q=block_q,
            interpret=_interpret())
    from repro.parallel.flags import opt
    if opt("PAGEDFLASH", default=False) and not _chunk_oracle():
        return ref.paged_flash_prefill_scan_ref(
            q, k_pool, v_pool, block_tables, start, window=window,
            use_lut=use_lut, scale=scale)
    return ref.paged_flash_prefill_ref(
        q, k_pool, v_pool, block_tables, start, window=window,
        use_lut=use_lut, scale=scale)


def selective_scan(dt, xs, bm, cm, a_log, h0, *, block_s=64, block_d=128):
    """Fused selective scan (mamba): VMEM-resident recurrence kernel on
    TPU (O(S·(d+state)) HBM traffic — EXPERIMENTS.md §Perf); jnp oracle
    elsewhere. Returns (y, h_last)."""
    B, S, D = dt.shape
    if _use_pallas() and S % min(block_s, S) == 0 \
            and D % min(block_d, D) == 0:
        return _ss.selective_scan(dt, xs, bm, cm, a_log, h0,
                                  block_s=min(block_s, S),
                                  block_d=min(block_d, D),
                                  interpret=_interpret())
    return _ss.selective_scan_ref(dt, xs, bm, cm, a_log, h0)
