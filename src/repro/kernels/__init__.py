"""Pallas TPU kernels for the paper's compute hot-spots, with pure-jnp
oracles (ref.py) and dispatching wrappers (ops.py)."""
from repro.kernels import ops, ref  # noqa: F401
