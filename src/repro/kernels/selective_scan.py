"""Fused selective-scan (Mamba-1) Pallas kernel.

The jnp lowering of the selective scan materializes (B, S, d_inner,
state) f32 decay/update tensors in HBM — 16× the token volume (state=16)
and the dominant memory term of the falcon-mamba train cell
(EXPERIMENTS.md §Perf). This kernel applies the paper's core discipline —
*keep the working set in compute-coupled memory* — to the SSM: the
(bs × bd × st) recurrence tensors are constructed, scanned, and consumed
entirely in VMEM; HBM sees only the (B, S, ·) inputs, the (B, S, bd)
output, and the (B, D, N) entry/exit states. Traffic drops from
O(S·d·st) to O(S·(d + st)).

Layout: grid = (B, d_inner/bd, S/bs) with the sequence dim innermost
("arbitrary" semantics); the running state h (bd, st) persists in VMEM
scratch across sequence tiles — the exact analogue of the WS-OCS
partial-sum buffer.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def _kernel(dt_ref, xs_ref, bm_ref, cm_ref, a_log_ref, h0_ref,
            o_ref, hout_ref, h_ref):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    dt = dt_ref[0].astype(jnp.float32)            # (bs, bd)
    xs = xs_ref[0].astype(jnp.float32)            # (bs, bd)
    bm = bm_ref[0].astype(jnp.float32)            # (bs, st)
    cm = cm_ref[0].astype(jnp.float32)            # (bs, st)
    A = -jnp.exp(a_log_ref[...].astype(jnp.float32))   # (bd, st)

    # (bs, bd, st) recurrence tensors — VMEM-resident only
    a = jnp.exp(dt[:, :, None] * A[None])
    b = (dt * xs)[:, :, None] * bm[:, None, :]
    # fold the carried state into step 0
    b = b.at[0].add(a[0] * h_ref[...])
    _, hs = jax.lax.associative_scan(_combine, (a, b), axis=0)
    h_ref[...] = hs[-1]
    y = jnp.einsum("sdn,sn->sd", hs, cm)          # (bs, bd)
    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(si == ns - 1)
    def _():
        hout_ref[0] = h_ref[...]


def selective_scan(dt: jax.Array, xs: jax.Array, bm: jax.Array,
                   cm: jax.Array, a_log: jax.Array, h0: jax.Array, *,
                   block_s: int = 64, block_d: int = 128,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """y[b,s,d] = Σ_n h[b,s,d,n]·C[b,s,n] with
    h_t = exp(dt_t·A)⊙h_{t-1} + (dt_t·x_t)⊗B_t,  A = −exp(a_log).

    dt, xs: (B, S, D); bm, cm: (B, S, N); a_log: (D, N); h0: (B, D, N).
    Returns (y (B,S,D) f32, h_last (B,D,N) f32).
    """
    B, S, D = dt.shape
    N = bm.shape[-1]
    bs = min(block_s, S)
    bd = min(block_d, D)
    assert S % bs == 0 and D % bd == 0, (S, bs, D, bd)

    return pl.pallas_call(
        _kernel,
        grid=(B, D // bd, S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),   # dt
            pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),   # xs
            pl.BlockSpec((1, bs, N), lambda b, d, s: (b, s, 0)),    # B
            pl.BlockSpec((1, bs, N), lambda b, d, s: (b, s, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, s: (d, 0)),          # A_log
            pl.BlockSpec((1, bd, N), lambda b, d, s: (b, d, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, s: (b, d, 0)),    # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],          # running h
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(dt, xs, bm, cm, a_log, h0)


def selective_scan_ref(dt, xs, bm, cm, a_log, h0) -> Tuple[jax.Array, jax.Array]:
    """Pure-jnp oracle (same algebra, HBM-materialized)."""
    dtf = dt.astype(jnp.float32)
    xsf = xs.astype(jnp.float32)
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dtf[..., None] * A)                       # (B,S,D,N)
    b = (dtf * xsf)[..., None] * bm.astype(jnp.float32)[:, :, None, :]
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, hs = jax.lax.associative_scan(_combine, (a, b), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cm.astype(jnp.float32))
    return y, hs[:, -1]
