"""Dataflow schedules and access-count model (paper §II-C, Table I).

GEMM convention (paper's): input A is (M, N), weight W is (N, K), output
O = A·W is (M, K). Tiles are m×n (input), n×k (weight), m×k (output).

Five dataflows are modeled:
  IS      input-stationary, no output buffering
  WS      weight-stationary, no output buffering
  IS_OS   input-stationary + output-stationary           [6]
  WS_OS   weight-stationary + output-stationary          [6]
  WS_OCS  weight-stationary + output-COLUMN-stationary   (this paper)

Two independent implementations are provided:
  * :func:`access_counts` — the closed-form Table-I formulas.
  * :func:`simulate_access` — an instrumented walk of the actual loop nest
    tracking buffer residency.  Property tests assert the two agree, which
    is how we validate the Table-I reproduction.

These counts drive ``sim.perf_model`` (latency/energy) and map onto the
Pallas kernel's grid orders (``kernels.ws_ocs_matmul``): the WS-OCS loop
nest here *is* the kernel's (K/k outer, M/m inner) grid with the weight
column panel held in VMEM.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterator, Tuple


class Dataflow(str, enum.Enum):
    IS = "is"
    WS = "ws"
    IS_OS = "is_os"
    WS_OS = "ws_os"
    WS_OCS = "ws_ocs"


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Problem (M, N, K) and tile (m, n, k) sizes, in elements."""

    M: int
    N: int
    K: int
    m: int
    n: int
    k: int

    def __post_init__(self):
        for dim, t in ((self.M, self.m), (self.N, self.n), (self.K, self.k)):
            assert t >= 1 and dim >= t, (dim, t)

    @property
    def Mm(self) -> int:
        return math.ceil(self.M / self.m)

    @property
    def Nn(self) -> int:
        return math.ceil(self.N / self.n)

    @property
    def Kk(self) -> int:
        return math.ceil(self.K / self.k)


def access_counts(df: Dataflow, tc: TileConfig) -> Dict[str, int]:
    """Closed-form Table-I element counts.

    Returns dict with keys: input / weight / output (external DRAM reads or
    writes) and cim_update (internal CIM weight-array writes).
    Output counts for non-OS flows include the partial-sum read-modify-
    write traffic ((N/n)·MK), matching the paper's Table I.
    """
    M, N, K = tc.M, tc.N, tc.K
    Mm, Nn, Kk = tc.Mm, tc.Nn, tc.Kk
    if df == Dataflow.IS:
        return dict(input=M * N, weight=Mm * N * K, output=Nn * M * K,
                    cim_update=Mm * N * K)
    if df == Dataflow.WS:
        return dict(input=Kk * M * N, weight=N * K, output=Nn * M * K,
                    cim_update=N * K)
    if df == Dataflow.IS_OS:
        return dict(input=M * N, weight=Mm * N * K, output=M * K,
                    cim_update=Mm * N * K)
    if df == Dataflow.WS_OS:
        return dict(input=Kk * M * N, weight=N * K, output=M * K,
                    cim_update=Mm * N * K)
    if df == Dataflow.WS_OCS:
        return dict(input=Kk * (M - tc.m) * N, weight=N * K, output=M * K,
                    cim_update=N * K)
    raise ValueError(df)


# ---------------------------------------------------------------------------
# Loop-nest schedules
# ---------------------------------------------------------------------------

def schedule(df: Dataflow, tc: TileConfig) -> Iterator[Tuple[int, int, int]]:
    """Yield (mi, ni, ki) tile coordinates in each dataflow's loop order."""
    Mm, Nn, Kk = tc.Mm, tc.Nn, tc.Kk
    if df in (Dataflow.IS, Dataflow.IS_OS):
        # input tile (mi, ni) outer-stationary; sweep weight columns k
        for mi in range(Mm):
            for ni in range(Nn):
                for ki in range(Kk):
                    yield mi, ni, ki
    elif df in (Dataflow.WS, Dataflow.WS_OS):
        # weight tile (ni, ki) stationary; sweep input rows m; outer over k
        for ki in range(Kk):
            for ni in range(Nn):
                for mi in range(Mm):
                    yield mi, ni, ki
    elif df == Dataflow.WS_OCS:
        # whole weight column panel W[:, ki] stationary (all ni at once);
        # stream input rows; partial column accumulates on-chip
        for ki in range(Kk):
            for mi in range(Mm):
                for ni in range(Nn):
                    yield mi, ni, ki
    else:
        raise ValueError(df)


def simulate_access(df: Dataflow, tc: TileConfig) -> Dict[str, int]:
    """Walk the loop nest with explicit buffer-residency tracking and count
    element traffic. Validates :func:`access_counts` (see tests).

    Buffer model per dataflow:
      IS/IS_OS : one input tile resident; weight tiles always fetched.
      WS/WS_OS : one weight tile resident (refetch on change); for the
                 *_OS variants the CIM array is rewritten per (mi) pass per
                 Table I's (M/m)·NK update term, while external weight
                 reads stay NK via the weight buffer.
      WS_OCS   : whole W[:, ki] panel resident (written once per ki);
                 input row-tile resident across the ni sweep and across
                 the ki loop for the first tile (input-reuse buffer).
      OS flows : output tile written once; non-OS flows spill partials
                 per ni step.
    """
    M, N, K = tc.M, tc.N, tc.K
    m, n, k = tc.m, tc.n, tc.k

    def tile_m(mi):  # actual tile extents (edge tiles may be ragged)
        return min(m, M - mi * m)

    def tile_n(ni):
        return min(n, N - ni * n)

    def tile_k(ki):
        return min(k, K - ki * k)

    counts = dict(input=0, weight=0, output=0, cim_update=0)
    resident_input = None   # (mi, ni) or for WS_OCS (mi,) with full row set
    resident_weight = None  # (ni, ki) / for WS_OCS panel ki
    out_written = set()

    if df == Dataflow.WS_OCS:
        seen_inputs = set()  # (mi, ni) pairs held by the input-reuse buffer
        for ki in range(tc.Kk):
            # load whole column panel once: N×k elements
            counts["weight"] += N * tile_k(ki)
            counts["cim_update"] += N * tile_k(ki)
            for mi in range(tc.Mm):
                for ni in range(tc.Nn):
                    # input-reuse buffer: the FIRST row-tile (mi==0) stays
                    # resident across ki iterations → (K/k)·(M−m)·N total
                    if mi == 0:
                        if (mi, ni) not in seen_inputs:
                            counts["input"] += tile_m(mi) * tile_n(ni)
                            seen_inputs.add((mi, ni))
                    else:
                        counts["input"] += tile_m(mi) * tile_n(ni)
                # column partial sums live on-chip; output written once
                counts["output"] += tile_m(mi) * tile_k(ki)
        return counts

    for (mi, ni, ki) in schedule(df, tc):
        if df in (Dataflow.IS, Dataflow.IS_OS):
            if resident_input != (mi, ni):
                counts["input"] += tile_m(mi) * tile_n(ni)
                resident_input = (mi, ni)
            counts["weight"] += tile_n(ni) * tile_k(ki)
            counts["cim_update"] += tile_n(ni) * tile_k(ki)
        else:  # WS, WS_OS
            if resident_weight != (ni, ki):
                counts["weight"] += tile_n(ni) * tile_k(ki)
                resident_weight = (ni, ki)
                if df == Dataflow.WS:
                    counts["cim_update"] += tile_n(ni) * tile_k(ki)
            if df == Dataflow.WS_OS:
                # Table I: WS_OS still rewrites the CIM array per input
                # pass — the OS accumulator occupies the array, forcing
                # (M/m)·NK updates even though DRAM reads stay NK.
                counts["cim_update"] += tile_n(ni) * tile_k(ki)
            counts["input"] += tile_m(mi) * tile_n(ni)

        if df in (Dataflow.IS_OS, Dataflow.WS_OS):
            if (mi, ki) not in out_written:
                counts["output"] += tile_m(mi) * tile_k(ki)
                out_written.add((mi, ki))
        else:  # partial-sum spill per n step
            counts["output"] += tile_m(mi) * tile_k(ki)

    return counts


def reduction_vs(df_new: Dataflow, df_old: Dataflow, tc: TileConfig,
                 keys=("input", "weight", "output")) -> float:
    """Fractional reduction of summed external traffic (Fig 8a-style)."""
    a = access_counts(df_new, tc)
    b = access_counts(df_old, tc)
    sa = sum(a[x] for x in keys)
    sb = sum(b[x] for x in keys)
    return 1.0 - sa / sb
