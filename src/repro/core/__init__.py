"""The paper's primary contribution as composable JAX modules.

* :mod:`repro.core.dataflow` — IS/WS/IS-OS/WS-OS/WS-OCS schedules and the
  Table-I access-count model.
* :mod:`repro.core.rcw` — read-compute/write overlap timing model.
* :mod:`repro.core.fusion` — LUT-64 group softmax, group RMS/LayerNorm,
  online-softmax attention (framework-level references for the kernels).
* :mod:`repro.core.quant` — INT4/INT8 quantization substrate.
"""
from repro.core import dataflow, fusion, quant, rcw  # noqa: F401
