"""Nonlinear operator fusion (paper §II-D), framework level.

Implements the paper's two fused nonlinear operators in pure jnp. The
Pallas kernels in ``repro.kernels`` reproduce these bit-for-bit (tested);
model code calls through ``repro.kernels.ops`` which dispatches.

* ``group_softmax`` — eq (1): a 64-segment piecewise-linear LUT replaces
  exp; inputs are offset by the *group* max (killing the global-max
  dependency); per-group partial sums ("partial accumulation") are merged
  online into the global denominator ("full accumulation").
* ``group_rmsnorm`` — eq (2): per-group partial Σx² with the global-RMS
  synchronization deferred and fused into the γ-scaling pass. The result
  is numerically the standard (global) RMSNorm — the grouping is a
  *latency* optimization, which the sim/ model accounts for.
* ``group_layernorm`` — the analogous group-stat + late-sync LayerNorm for
  archs that use LN (command-r, starcoder2, whisper). See DESIGN.md §4.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# 64-segment piecewise-linear exp LUT
# ---------------------------------------------------------------------------

LUT_SEGMENTS = 64
LUT_LO = -16.0  # exp(-16) ≈ 1.1e-7: below fp16 softmax significance
LUT_HI = 0.0


def build_exp_lut(segments: int = LUT_SEGMENTS, lo: float = LUT_LO,
                  hi: float = LUT_HI):
    """Per-segment (a, b) with exp(x) ≈ a·x + b on [lo, hi], chords through
    segment endpoints (max error e^hi·w²/8 at segment centers). Built in
    numpy so cached/global LUTs are trace-safe constants (never tracers)."""
    import numpy as np
    edges = np.linspace(lo, hi, segments + 1, dtype=np.float32)
    e = np.exp(edges)
    a = (e[1:] - e[:-1]) / (edges[1:] - edges[:-1])
    b = e[:-1] - a * edges[:-1]
    return jnp.asarray(a), jnp.asarray(b)


import numpy as _np

_edges = _np.linspace(LUT_LO, LUT_HI, LUT_SEGMENTS + 1, dtype=_np.float32)
_e = _np.exp(_edges)
_LUT_A_NP = (_e[1:] - _e[:-1]) / (_edges[1:] - _edges[:-1])
_LUT_B_NP = _e[:-1] - _LUT_A_NP * _edges[:-1]


def _default_lut():
    # numpy constants lifted to jnp at call time: embeds as a trace
    # constant (never a cached tracer) and supports tracer indexing
    return jnp.asarray(_LUT_A_NP), jnp.asarray(_LUT_B_NP)


def lut_exp(x: jax.Array, lut: Optional[Tuple[jax.Array, jax.Array]] = None,
            lo: float = LUT_LO, hi: float = LUT_HI) -> jax.Array:
    """Piecewise-linear exp(x) for x ≤ 0. Values below ``lo`` flush to an
    exact 0 — the paper's underflow guard (exp(-16) ≈ 1.1e-7 is below
    FP16 softmax significance)."""
    a, b = lut if lut is not None else _default_lut()
    segments = a.shape[0]
    xf = x.astype(jnp.float32)
    xc = jnp.clip(xf, lo, hi)
    seg_w = (hi - lo) / segments
    idx = jnp.clip(((xc - lo) / seg_w).astype(jnp.int32), 0, segments - 1)
    y = a[idx] * xc + b[idx]
    return jnp.where(xf < lo, 0.0, y)


# ---------------------------------------------------------------------------
# Group softmax (eq 1)
# ---------------------------------------------------------------------------

def group_softmax(x: jax.Array, group_size: int = 64, use_lut: bool = True,
                  where: Optional[jax.Array] = None) -> jax.Array:
    """Softmax over the last axis, evaluated in groups of ``group_size``.

    Per group: offset by group max, LUT-exp ("partial accumulation" — all
    groups exponentiate in parallel), per-group sum; groups are then merged
    online (log-sum-exp algebra) and the normalization is fused into the
    final scale. With exact exp this is bit-equivalent to softmax; with
    the LUT it matches the paper's approximation.
    """
    orig_dtype = x.dtype
    n = x.shape[-1]
    g = min(group_size, n)
    pad = (-n) % g
    xf = x.astype(jnp.float32)
    if where is not None:
        xf = jnp.where(where, xf, -jnp.inf)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                     constant_values=-jnp.inf)
    G = xf.shape[-1] // g
    xg = xf.reshape(xf.shape[:-1] + (G, g))

    exp = lut_exp if use_lut else jnp.exp
    m_g = jnp.max(xg, axis=-1, keepdims=True)               # group max
    m_g_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)       # all-masked group
    p = exp(xg - m_g_safe)                                   # partial accum
    p = jnp.where(jnp.isfinite(xg), p, 0.0)
    s_g = jnp.sum(p, axis=-1, keepdims=True)                 # full accum

    m = jnp.max(m_g, axis=-2, keepdims=True)                 # online merge
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    r = exp(m_g_safe - m_safe) * jnp.where(jnp.isfinite(m_g), 1.0, 0.0)
    denom = jnp.sum(s_g * r, axis=-2, keepdims=True)
    out = p * r / jnp.maximum(denom, 1e-30)

    out = out.reshape(xf.shape)
    if pad:
        out = out[..., :n]
    return out.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Group RMSNorm (eq 2) and group LayerNorm
# ---------------------------------------------------------------------------

def group_rmsnorm(x: jax.Array, gamma: jax.Array, group_size: int = 128,
                  eps: float = 1e-6) -> jax.Array:
    """RMSNorm with per-group partial Σx² and the global-RMS sync fused
    into the γ scale (eq 2 + the paper's late-sync refinement)."""
    orig_dtype = x.dtype
    n = x.shape[-1]
    g = min(group_size, n)
    assert n % g == 0, (n, g)
    xf = x.astype(jnp.float32)
    xg = xf.reshape(xf.shape[:-1] + (n // g, g))
    partial_ms = jnp.mean(jnp.square(xg), axis=-1)           # per-group stat
    global_ms = jnp.mean(partial_ms, axis=-1, keepdims=True)  # late sync
    inv = jax.lax.rsqrt(global_ms + eps)                      # fused w/ γ
    out = xf * inv * gamma.astype(jnp.float32)
    return out.astype(orig_dtype)


def group_layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                    group_size: int = 128, eps: float = 1e-5) -> jax.Array:
    """LayerNorm via per-group partial (Σx, Σx²) merged late — the
    paper's group-stat idea applied to LN archs (DESIGN.md §4)."""
    orig_dtype = x.dtype
    n = x.shape[-1]
    g = min(group_size, n)
    assert n % g == 0, (n, g)
    xf = x.astype(jnp.float32)
    xg = xf.reshape(xf.shape[:-1] + (n // g, g))
    s1 = jnp.sum(xg, axis=-1)
    s2 = jnp.sum(jnp.square(xg), axis=-1)
    mean = jnp.sum(s1, axis=-1, keepdims=True) / n
    var = jnp.sum(s2, axis=-1, keepdims=True) / n - jnp.square(mean)
    inv = jax.lax.rsqrt(var + eps)
    out = (xf - mean) * inv * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Online-softmax attention reference (ties eq 1 to the flash kernel)
# ---------------------------------------------------------------------------

def online_softmax_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             causal: bool = True, use_lut: bool = False,
                             scale: Optional[float] = None,
                             block_k: int = 128) -> jax.Array:
    """O(S) -memory attention: KV is consumed in blocks with running
    (max, denom, acc) state — the paper's online-softmax regime [7] that
    the group-softmax fusion accelerates. Shapes: q (B,H,Sq,D), k/v
    (B,H,Sk,D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    exp = lut_exp if use_lut else jnp.exp
    qf = q.astype(jnp.float32) * scale
    nblk = -(-Sk // block_k)
    padk = nblk * block_k - Sk

    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, padk), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, padk), (0, 0)))
    kb = kf.reshape(B, H, nblk, block_k, D)
    vb = vf.reshape(B, H, nblk, block_k, D)

    q_pos = jnp.arange(Sq)[:, None]

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, start = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk)
        kpos = start + jnp.arange(block_k)[None, :]
        mask = kpos < Sk
        if causal:
            mask = mask & (kpos <= q_pos + (Sk - Sq))
        s = jnp.where(mask, s, -jnp.inf)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_blk)
        m_new_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = exp(s - m_new_safe)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), exp(m - m_new_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    starts = jnp.arange(nblk) * block_k
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), starts))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
