"""Quantization substrate: symmetric INT4 / INT8 with per-channel or
per-group scales, and nibble packing for INT4 weight storage.

The paper runs Llama2-7B with INT4 weights / INT8 activations / FP16
nonlinear functions. On TPU there is no native INT4 MAC mode, so INT4
weights are stored nibble-packed (two values per uint8) — preserving the
paper's *traffic and residency* economics — and dequantized to int8/bf16 at
the MXU boundary inside the kernel (see DESIGN.md §8.3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

INT4_MIN, INT4_MAX = -8, 7
INT8_MIN, INT8_MAX = -128, 127


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How a tensor class is quantized.

    mode: "w4a8" (paper default), "w8a8", or "bf16" (no quantization).
    group_size: contraction-dim group size for weight scales; None means
        per-output-channel scales.
    """

    mode: str = "w4a8"
    group_size: Optional[int] = 128

    @property
    def weight_bits(self) -> int:
        return {"w4a8": 4, "w8a8": 8, "bf16": 16}[self.mode]

    @property
    def act_bits(self) -> int:
        return {"w4a8": 8, "w8a8": 8, "bf16": 16}[self.mode]


def _absmax_scale(x: jax.Array, axis, qmax: int) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_int8(x: jax.Array, axis=-1):
    """Symmetric per-axis int8 quantization. Returns (q:int8, scale:f32)."""
    scale = _absmax_scale(x.astype(jnp.float32), axis, INT8_MAX)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), INT8_MIN, INT8_MAX)
    return q.astype(jnp.int8), scale


def quantize_int4(x: jax.Array, axis=0, group_size: Optional[int] = None):
    """Symmetric int4 quantization of a 2D weight (N, K) along the
    contraction axis ``axis`` (=0), optionally in groups of ``group_size``
    rows sharing one scale per output column.

    Returns (q:int8 in [-8,7], scale:f32 broadcastable to x).
    """
    x = x.astype(jnp.float32)
    if group_size is None:
        scale = _absmax_scale(x, axis, INT4_MAX)
    else:
        n = x.shape[axis]
        assert n % group_size == 0, (n, group_size)
        g = n // group_size
        xg = x.reshape(x.shape[:axis] + (g, group_size) + x.shape[axis + 1 :])
        sg = _absmax_scale(xg, axis + 1, INT4_MAX)  # (..., g, 1, ...)
        scale = jnp.broadcast_to(sg, xg.shape).reshape(x.shape)
    q = jnp.clip(jnp.round(x / scale), INT4_MIN, INT4_MAX)
    return q.astype(jnp.int8), scale


def group_scales(x: jax.Array, group_size: int, axis: int = 0) -> jax.Array:
    """Compact (G, K) scale tensor for a (N, K) weight with N//group_size
    groups (used by the Pallas kernel, which broadcasts in-kernel)."""
    x = x.astype(jnp.float32)
    n = x.shape[axis]
    assert n % group_size == 0
    xg = x.reshape((n // group_size, group_size) + x.shape[1:])
    return _absmax_scale(xg, 1, INT4_MAX)[:, 0]  # (G, K)


def pack_int4(q: jax.Array, axis: int = 0) -> jax.Array:
    """Pack int4 values (stored as int8 in [-8,7]) two-per-byte along
    ``axis``. Even indices go to the low nibble."""
    assert q.shape[axis] % 2 == 0
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = jax.lax.slice_in_dim(u, 0, u.shape[axis], stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(u, 1, u.shape[axis], stride=2, axis=axis)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jax.Array, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pack_int4` → int8 values in [-8, 7]."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # (..., n/2, 2, ...)
    shape = list(p.shape)
    shape[axis] = shape[axis] * 2
    return stacked.reshape(shape)


@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """An INT4/INT8 quantized (N, K) weight ready for the WS-OCS kernel.

    For w4: ``data`` is uint8 (N//2, K) nibble-packed along N.
    For w8: ``data`` is int8 (N, K).
    ``scale`` is f32 (G, K) with G = N // group_size (or (1, K))."""

    data: jax.Array
    scale: jax.Array
    bits: int
    group_size: int
    shape: tuple  # logical (N, K)

    def dequantize(self) -> jax.Array:
        q = unpack_int4(self.data, axis=0) if self.bits == 4 else self.data
        n, k = self.shape
        g = self.scale.shape[0]
        sf = jnp.repeat(self.scale, n // g, axis=0)
        return q.astype(jnp.float32) * sf


def quantize_weight(w: jax.Array, cfg: QuantConfig) -> QuantizedWeight:
    """Quantize a (N, K) weight per ``cfg`` (contraction dim = 0)."""
    n, k = w.shape
    gs = cfg.group_size or n
    if n % gs != 0:  # fall back to per-channel when groups don't divide
        gs = n
    if cfg.weight_bits == 4:
        scale = group_scales(w, gs)
        sf = jnp.repeat(scale, gs, axis=0)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / sf), INT4_MIN, INT4_MAX)
        return QuantizedWeight(pack_int4(q.astype(jnp.int8), axis=0), scale, 4, gs, (n, k))
    elif cfg.weight_bits == 8:
        scale = _absmax_scale(w.astype(jnp.float32), 0, INT8_MAX)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), INT8_MIN, INT8_MAX)
        return QuantizedWeight(q.astype(jnp.int8), scale.reshape(1, k), 8, n, (n, k))
    raise ValueError(f"no quantized storage for {cfg.mode}")
