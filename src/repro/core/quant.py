"""Quantization substrate: symmetric INT4 / INT8 with per-channel or
per-group scales, and nibble packing for INT4 weight storage.

The paper runs Llama2-7B with INT4 weights / INT8 activations / FP16
nonlinear functions. On TPU there is no native INT4 MAC mode, so INT4
weights are stored nibble-packed (two values per uint8) — preserving the
paper's *traffic and residency* economics — and dequantized to int8/bf16 at
the MXU boundary inside the kernel (see DESIGN.md §8.3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

INT4_MIN, INT4_MAX = -8, 7
INT8_MIN, INT8_MAX = -128, 127


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How a tensor class is quantized.

    mode: "w4a8" (paper default), "w8a8", or "bf16" (no quantization).
    group_size: contraction-dim group size for weight scales; None means
        per-output-channel scales.
    """

    mode: str = "w4a8"
    group_size: Optional[int] = 128

    @property
    def weight_bits(self) -> int:
        return {"w4a8": 4, "w8a8": 8, "bf16": 16}[self.mode]

    @property
    def act_bits(self) -> int:
        return {"w4a8": 8, "w8a8": 8, "bf16": 16}[self.mode]


def _absmax_scale(x: jax.Array, axis, qmax: int) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_int8(x: jax.Array, axis=-1):
    """Symmetric per-axis int8 quantization. Returns (q:int8, scale:f32)."""
    scale = _absmax_scale(x.astype(jnp.float32), axis, INT8_MAX)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), INT8_MIN, INT8_MAX)
    return q.astype(jnp.int8), scale


def quantize_int4(x: jax.Array, axis=0, group_size: Optional[int] = None):
    """Symmetric int4 quantization of a 2D weight (N, K) along the
    contraction axis ``axis`` (=0), optionally in groups of ``group_size``
    rows sharing one scale per output column.

    Returns (q:int8 in [-8,7], scale:f32 broadcastable to x).
    """
    x = x.astype(jnp.float32)
    if group_size is None:
        scale = _absmax_scale(x, axis, INT4_MAX)
    else:
        n = x.shape[axis]
        assert n % group_size == 0, (n, group_size)
        g = n // group_size
        xg = x.reshape(x.shape[:axis] + (g, group_size) + x.shape[axis + 1 :])
        sg = _absmax_scale(xg, axis + 1, INT4_MAX)  # (..., g, 1, ...)
        scale = jnp.broadcast_to(sg, xg.shape).reshape(x.shape)
    q = jnp.clip(jnp.round(x / scale), INT4_MIN, INT4_MAX)
    return q.astype(jnp.int8), scale


def group_scales(x: jax.Array, group_size: int, axis: int = 0) -> jax.Array:
    """Compact (G, K) scale tensor for a (N, K) weight with N//group_size
    groups (used by the Pallas kernel, which broadcasts in-kernel)."""
    x = x.astype(jnp.float32)
    n = x.shape[axis]
    assert n % group_size == 0
    xg = x.reshape((n // group_size, group_size) + x.shape[1:])
    return _absmax_scale(xg, 1, INT4_MAX)[:, 0]  # (G, K)


def pack_int4(q: jax.Array, axis: int = 0) -> jax.Array:
    """Pack int4 values (stored as int8 in [-8,7]) two-per-byte along
    ``axis``. Even indices go to the low nibble. Odd lengths are
    zero-padded to the next byte; pass ``n=`` to :func:`unpack_int4` to
    trim the pad on the way back."""
    if q.shape[axis] % 2 != 0:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = jax.lax.slice_in_dim(u, 0, u.shape[axis], stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(u, 1, u.shape[axis], stride=2, axis=axis)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jax.Array, axis: int = 0,
                n: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`pack_int4` → int8 values in [-8, 7].

    ``n`` trims the trailing zero-pad byte nibble that ``pack_int4``
    adds for odd lengths (defaults to the full 2×packed length)."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # (..., n/2, 2, ...)
    shape = list(p.shape)
    shape[axis] = shape[axis] * 2
    out = stacked.reshape(shape)
    if n is not None and n != shape[axis]:
        out = jax.lax.slice_in_dim(out, 0, n, axis=axis)
    return out


@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """An INT4/INT8 quantized (N, K) weight ready for the WS-OCS kernel.

    For w4: ``data`` is uint8 (N//2, K) nibble-packed along N.
    For w8: ``data`` is int8 (N, K).
    ``scale`` is f32 (G, K) with G = N // group_size (or (1, K))."""

    data: jax.Array
    scale: jax.Array
    bits: int
    group_size: int
    shape: tuple  # logical (N, K)

    def dequantize(self) -> jax.Array:
        n, k = self.shape
        q = unpack_int4(self.data, axis=0, n=n) if self.bits == 4 else self.data
        g = self.scale.shape[0]
        sf = jnp.repeat(self.scale, n // g, axis=0)
        return q.astype(jnp.float32) * sf


def quantize_weight(w: jax.Array, cfg: QuantConfig) -> QuantizedWeight:
    """Quantize a (N, K) weight per ``cfg`` (contraction dim = 0)."""
    n, k = w.shape
    gs = cfg.group_size or n
    if n % gs != 0:  # fall back to per-channel when groups don't divide
        gs = n
    if cfg.weight_bits == 4:
        scale = group_scales(w, gs)
        sf = jnp.repeat(scale, gs, axis=0)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / sf), INT4_MIN, INT4_MAX)
        return QuantizedWeight(pack_int4(q.astype(jnp.int8), axis=0), scale, 4, gs, (n, k))
    elif cfg.weight_bits == 8:
        scale = _absmax_scale(w.astype(jnp.float32), 0, INT8_MAX)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), INT8_MIN, INT8_MAX)
        return QuantizedWeight(q.astype(jnp.int8), scale.reshape(1, k), 8, n, (n, k))
    raise ValueError(f"no quantized storage for {cfg.mode}")


# ---------------------------------------------------------------------------
# Structured N:M weight sparsity (DESIGN.md §14)
#
# Two granularities over the contraction dim (N), both magnitude-pruned:
#
# * "col" — classic per-output-column N:M (2:4 default): each column keeps
#   the n largest of every m consecutive rows independently. Metadata is a
#   packed BITMASK, uint8 (N//8, K): 1 bit per original position, so a w4
#   2:4 weight streams 0.5·4 + 1 = 3 bits/element instead of 4 (25% fewer
#   panel DMA bytes; the sparse kernels expand it back to a dense tile
#   in VMEM with a rank/cumsum select — no gather).
# * "row" — the flexible per-row N-of-M variant: whole contraction rows
#   are kept/dropped together (ranked by column-aggregated magnitude),
#   shared across all output columns. Metadata is the kept-row index
#   vector, int32 (Nc,), scalar-prefetched by the kernels; the MACs for
#   dropped rows are genuinely skipped (x[:, kept] @ Wc).
#
# Pruning happens BEFORE quantization on the dense float weight, and the
# scales are computed on the masked dense weight — so a sparse checkpoint
# carries bit-identical (data, scale) to its dense-masked equivalent and
# serves token-identically through the default dense-mask lowering.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Structured N:M sparsity spec: keep ``n`` of every ``m`` rows.

    granularity: "col" (per-output-column N:M) or "row" (whole
    contraction rows, flexible N-of-M)."""

    n: int = 2
    m: int = 4
    granularity: str = "col"

    @property
    def keep_frac(self) -> float:
        return self.n / self.m

    @property
    def key(self) -> str:
        """Pytree leaf name carrying the metadata tensor. n/m are encoded
        in the KEY (static under vmap/scan) and granularity is recovered
        from the leaf's ndim (1 → row indices, 2 → column bitmask)."""
        return f"sp{self.n}of{self.m}"


def parse_sparsity(spec: str) -> Optional[SparsityConfig]:
    """Parse ``cfg.sparsity``: "" → None, "2:4" → col, "2:4:row" → row."""
    if not spec:
        return None
    parts = spec.split(":")
    if len(parts) not in (2, 3):
        raise ValueError(f"bad sparsity spec {spec!r} (want 'n:m[:row]')")
    n, m = int(parts[0]), int(parts[1])
    gran = parts[2] if len(parts) == 3 else "col"
    if gran not in ("col", "row"):
        raise ValueError(f"bad sparsity granularity {gran!r} in {spec!r}")
    if not 0 < n < m:
        raise ValueError(f"bad sparsity ratio {n}:{m} in {spec!r}")
    return SparsityConfig(n, m, gran)


def nm_prune_mask(w: jax.Array, sp: SparsityConfig) -> jax.Array:
    """Boolean keep-mask (N, K) with exactly ``n`` kept per ``m``-group.

    col: per-column |w| ranking inside each m-group. row: rows ranked by
    column-aggregated |w| (sum over K), mask constant across columns.
    Ties break toward the lower row index (stable argsort)."""
    n_rows, k = w.shape
    assert n_rows % sp.m == 0, (n_rows, sp.m)
    score = jnp.abs(w.astype(jnp.float32))
    if sp.granularity == "row":
        score = jnp.sum(score, axis=1, keepdims=True)  # (N, 1)
    g2 = n_rows // sp.m
    sg = score.reshape(g2, sp.m, -1)
    # rank[j] = how many entries beat entry j (descending, stable)
    order = jnp.argsort(-sg, axis=1, stable=True)
    rank = jnp.argsort(order, axis=1, stable=True)
    mask = (rank < sp.n).reshape(n_rows, score.shape[1])
    return jnp.broadcast_to(mask, (n_rows, k))


def pack_bitmask(mask: jax.Array) -> jax.Array:
    """Pack a boolean (N, K) mask to uint8 (N//8, K); bit i of byte b is
    row 8b+i (little-endian within the byte)."""
    n_rows, k = mask.shape
    assert n_rows % 8 == 0, n_rows
    m8 = mask.astype(jnp.uint8).reshape(n_rows // 8, 8, k)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    return jnp.sum(m8 << shifts, axis=1).astype(jnp.uint8)


def unpack_bitmask(packed: jax.Array, n_rows: int) -> jax.Array:
    """Inverse of :func:`pack_bitmask` → bool (n_rows, K)."""
    n8, k = packed.shape
    assert n8 * 8 == n_rows, (n8, n_rows)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    bits = (packed[:, None, :] >> shifts) & 1
    return bits.reshape(n_rows, k).astype(bool)


def mask_rank(mask: jax.Array, m: int) -> jax.Array:
    """0-based rank of each kept position among the kept entries of its
    m-group (exclusive cumsum; value for dropped positions is unused)."""
    n_rows, k = mask.shape
    mg = mask.astype(jnp.int32).reshape(n_rows // m, m, k)
    return (jnp.cumsum(mg, axis=1) - mg).reshape(n_rows, k)


def compact_nm(q: jax.Array, mask: jax.Array, sp: SparsityConfig):
    """Compress a dense (N, K) value tensor to its (Nc, K) nonzeros plus
    the metadata tensor (col → packed bitmask, row → kept indices).

    Kept values stay in ascending row order, so the round-trip through
    :func:`expand_nm` is exact."""
    n_rows, k = q.shape
    nc = n_rows * sp.n // sp.m
    g2 = n_rows // sp.m
    if sp.granularity == "row":
        keep_row = mask[:, 0]
        # kept row indices, ascending (exactly nc of them by construction)
        kept = jnp.sort(jnp.where(keep_row, jnp.arange(n_rows), n_rows))[:nc]
        return jnp.take(q, kept, axis=0), kept.astype(jnp.int32)
    # col: within each m-group, kept offsets sort ahead of dropped ones
    off = jnp.arange(sp.m).reshape(1, sp.m, 1)
    keyed = jnp.where(mask.reshape(g2, sp.m, k), off, sp.m + off)
    pos = jnp.sort(keyed, axis=1)[:, : sp.n, :] % sp.m
    vals = jnp.take_along_axis(q.reshape(g2, sp.m, k), pos, axis=1)
    return vals.reshape(nc, k), pack_bitmask(mask)


def expand_nm(vals: jax.Array, idx: jax.Array, sp: SparsityConfig,
              n_rows: int) -> jax.Array:
    """Exact inverse of :func:`compact_nm`: (Nc, K) values + metadata →
    dense (N, K) with zeros in the pruned slots."""
    nc, k = vals.shape
    if sp.granularity == "row":
        return jnp.zeros((n_rows, k), vals.dtype).at[idx].set(vals)
    mask = unpack_bitmask(idx, n_rows)
    rank = mask_rank(mask, sp.m)
    g2 = n_rows // sp.m
    vg = vals.reshape(g2, sp.n, k)
    gathered = jnp.take_along_axis(
        vg, jnp.minimum(rank, sp.n - 1).reshape(g2, sp.m, k), axis=1)
    return (gathered.reshape(n_rows, k)
            * mask.astype(vals.dtype)).astype(vals.dtype)


@dataclasses.dataclass(frozen=True)
class SparseQuantizedWeight:
    """Compressed N:M-sparse quantized (N, K) weight for the sparse
    WS-OCS kernels.

    ``data``: packed compressed nonzeros — uint8 (Nc//2, K) for w4,
    int8 (Nc, K) for w8 (same pack format as the dense path).
    ``scale``: f32 (G, K), computed on the MASKED DENSE weight — bit-
    identical to the dense-masked equivalent checkpoint's scales.
    ``idx``: col → uint8 packed bitmask (N//8, K); row → int32 (Nc,)
    kept-row indices (ascending)."""

    data: jax.Array
    scale: jax.Array
    idx: jax.Array
    bits: int
    group_size: int
    sp: SparsityConfig
    shape: tuple  # logical dense (N, K)

    def expand_q(self) -> jax.Array:
        """Dense int8 (N, K) codes with zeros in pruned slots — exactly
        the codes the dense-masked equivalent checkpoint stores."""
        n_rows, _ = self.shape
        nc = n_rows * self.sp.n // self.sp.m
        vals = (unpack_int4(self.data, axis=0, n=nc)
                if self.bits == 4 else self.data)
        return expand_nm(vals, self.idx, self.sp, n_rows)

    def dequantize(self) -> jax.Array:
        n_rows, _ = self.shape
        sf = jnp.repeat(self.scale, n_rows // self.scale.shape[0], axis=0)
        return self.expand_q().astype(jnp.float32) * sf


def sparse_ok(n_rows: int, sp: SparsityConfig) -> bool:
    """Can a (n_rows, K) weight be stored N:M-compressed? Needs whole
    m-groups, byte-aligned bitmask rows (col), and an even nonzero count
    for nibble packing."""
    if n_rows % sp.m != 0:
        return False
    if sp.granularity == "col" and n_rows % 8 != 0:
        return False
    return (n_rows * sp.n // sp.m) % 2 == 0


def sparsify_weight(w: jax.Array, cfg: QuantConfig,
                    sp: SparsityConfig) -> SparseQuantizedWeight:
    """Magnitude-prune ``w`` to N:M structure, then quantize the masked
    dense weight per ``cfg`` (prune-then-quantize: scales — and therefore
    every dequantized value — match the dense-masked checkpoint exactly),
    then compact storage to the nonzeros + metadata."""
    n_rows, k = w.shape
    assert sparse_ok(n_rows, sp), (w.shape, sp)
    mask = nm_prune_mask(w, sp)
    qw = quantize_weight(w.astype(jnp.float32) * mask, cfg)
    gs = qw.group_size
    # uniform compressed rows per scale group keeps the (G, K) scale
    # layout valid in compressed space; fall back to per-channel if not
    if gs % sp.m != 0:
        qw = quantize_weight(w.astype(jnp.float32) * mask,
                             dataclasses.replace(cfg, group_size=None))
        gs = qw.group_size
    q_dense = (unpack_int4(qw.data, axis=0, n=n_rows)
               if qw.bits == 4 else qw.data)
    vals, idx = compact_nm(q_dense, mask, sp)
    data = pack_int4(vals, axis=0) if qw.bits == 4 else vals
    return SparseQuantizedWeight(data, qw.scale, idx, qw.bits, gs, sp,
                                 (n_rows, k))
