"""Read-compute/write (RCW) timing model (paper §II-B).

The CIM macro's two-phase operation — Phase 1 reads/latches weights into
the adder tree, Phase 2 computes MACs *while* the next weights are written
into the SRAM array — is, at the scheduling level, a double-buffered
pipeline: stage i's compute overlaps stage i+1's weight fill.

This module gives the closed-form latency of that pipeline; it drives
``sim.perf_model`` (reproducing the paper's 21.59 % decode reduction) and
documents the exact schedule the Pallas kernel's ``rcw=True`` double-buffer
implements on TPU (HBM→VMEM DMA overlapped with MXU compute).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class RCWStage:
    """One weight-panel stage: fill time and compute time (seconds)."""

    t_fill: float
    t_compute: float


def latency_serial(stages: Sequence[RCWStage]) -> float:
    """Baseline (no RCW): every fill blocks compute."""
    return sum(s.t_fill + s.t_compute for s in stages)


def latency_rcw(stages: Sequence[RCWStage]) -> float:
    """RCW: fill of stage i+1 hides behind compute of stage i.

    latency = fill_0 + Σ_i max(compute_i, fill_{i+1}) + compute_last's
    remainder — i.e. the classic 2-deep software pipeline. Fill can only
    hide behind compute that exists; with compute ≪ fill (decode) the
    pipeline is fill-bound and the residual fill is exposed.
    """
    if not stages:
        return 0.0
    t = stages[0].t_fill
    for i, s in enumerate(stages):
        nxt_fill = stages[i + 1].t_fill if i + 1 < len(stages) else 0.0
        t += max(s.t_compute, nxt_fill)
    return t


def latency_uniform(n_stages: int, t_fill: float, t_compute: float,
                    rcw: bool) -> float:
    """Uniform-stage convenience wrapper."""
    stages = [RCWStage(t_fill, t_compute)] * n_stages
    return latency_rcw(stages) if rcw else latency_serial(stages)


def rcw_speedup(n_stages: int, t_fill: float, t_compute: float) -> float:
    """Fractional latency reduction from RCW for uniform stages."""
    base = latency_uniform(n_stages, t_fill, t_compute, rcw=False)
    over = latency_uniform(n_stages, t_fill, t_compute, rcw=True)
    return 1.0 - over / base
