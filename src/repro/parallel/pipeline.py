"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``pipeline_apply`` runs a homogeneous stage function over S mesh-resident
stages with M microbatches using ``shard_map`` + ``collective_permute``
(the jax-native expression of the inter-stage point-to-point pattern —
DESIGN.md §5). The schedule is the classic (M + S − 1)-tick GPipe wave:
bubble fraction (S−1)/(M+S−1).

The production dry-run uses DP×TP (a 72B fits a v5e-256 pod without PP);
this module is the scale-out escape hatch for deeper models / smaller
pods and is exercised in tests on a multi-device host mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params, x,
                   n_micro: int, axis: str = "stage"):
    """Run x through S sequential stages, pipelined over microbatches.

    stage_params: pytree with leaves stacked on a leading S dim.
    x: (B, ...) — B must be divisible by n_micro.
    Returns stage_{S-1}(…stage_0(x)) with shape (B, ...).
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    xs = x.reshape((n_micro, mb) + x.shape[1:])

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)
    perm = [(i, i + 1) for i in range(S - 1)]

    @partial(shard_map, mesh=mesh, in_specs=(p_specs, P()),
             out_specs=P(), check_rep=False)
    def run(params_local, xs_rep):
        params1 = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        out = jnp.zeros_like(xs_rep)
        buf = jnp.zeros_like(xs_rep[0])
        for t in range(n_micro + S - 1):
            feed = xs_rep[min(t, n_micro - 1)]
            cur = jnp.where(idx == 0, feed, buf)
            y = stage_fn(params1, cur)
            j = t - (S - 1)
            if 0 <= j < n_micro:
                out = out.at[j].set(jnp.where(idx == S - 1, y, out[j]))
            if S > 1:
                buf = jax.lax.ppermute(y, axis, perm)
        # only the last stage holds real outputs; broadcast via psum
        mask = (idx == S - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    y = run(stage_params, xs)
    return y.reshape((B,) + y.shape[2:])


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
