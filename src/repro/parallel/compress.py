"""Compressed cross-pod gradient reduction.

On the multi-pod mesh the "pod" axis rides DCN (an order of magnitude
slower than ICI), so the pod-level gradient all-reduce is the scaling
bottleneck at 1000+ nodes. ``compressed_psum_pod`` performs the pod
all-reduce in int8 with per-chunk scales under ``shard_map`` — an ~2×
(bf16) / ~4× (f32) wire-byte reduction with bounded quantization error
(error-feedback residual optional at the trainer level).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _quantize_chunked(x: jax.Array, chunk: int = 4096):
    flat = x.reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    c = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(c), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize_chunked(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad] if pad else flat
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed psum over ``axis_name`` (call inside shard_map).

    Each participant quantizes its local tensor to int8 + per-chunk f32
    scales, all-gathers the compact representation over the (slow) axis,
    dequantizes and sums locally — total wire bytes ≈ N·(bytes/4 + scale
    overhead) instead of the 2·bytes ring all-reduce."""
    q, scale, pad = _quantize_chunked(x)
    qg = jax.lax.all_gather(q, axis_name)          # (N, chunks, chunk) int8
    sg = jax.lax.all_gather(scale, axis_name)
    parts = qg.astype(jnp.float32) * sg
    total = jnp.sum(parts, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape)


def make_pod_grad_reducer(mesh: Mesh, grad_specs):
    """Returns f(grads)→grads that all-reduces over the "pod" axis with
    int8 compression, leaving intra-pod reduction to GSPMD. No-op when
    the mesh has no pod axis."""
    if "pod" not in mesh.shape:
        return lambda g: g

    def reduce_leaf(spec):
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(spec,), out_specs=spec, check_rep=False)
        def f(g):
            return compressed_psum(g, "pod") / mesh.shape["pod"]
        return f

    def reducer(grads):
        return jax.tree.map(
            lambda g, s: reduce_leaf(s)(g), grads, grad_specs)

    return reducer
