"""Optimization flags (EXPERIMENTS.md §Perf) — and the single reference
table of every ``REPRO_*`` environment flag in the tree.

The hillclimbed optimizations are framework DEFAULTS; each can be
disabled for A/B against the paper-faithful baseline. Flags outside the
``REPRO_OPT_*`` family are read elsewhere (reader noted per row) but
documented here so there is exactly one place to look.

=====================  =======  =========================================
flag                   default  meaning (reader)
=====================  =======  =========================================
REPRO_OPT_FLASH        1        off-TPU long-seq attention uses the
                                O(S)-memory flash-scan oracle; 0 = the
                                materialized-score oracle (here +
                                kernels/ops.py)
REPRO_OPT_SEQKV        1        head-dim-sharded KV cache; 0 = baseline
                                decode layout (here)
REPRO_OPT_EPMODEL      1        experts sharded over "model"; 0 =
                                baseline "data" MoE layout (here)
REPRO_OPT_GRADRS       1        pin grads to the param sharding
                                (measured no-op: GSPMD already
                                propagates it — §Perf, refuted) (here)
REPRO_OPT_EPMOE        0        (refuted ablation) pin dispatched
                                tokens E→"data" (here)
REPRO_OPT_PAGEDFLASH   0        off-TPU chunk-prefill/verify attention
                                lowers to the O(written-prefix)
                                online-softmax scan instead of the
                                bit-exact PR 5 gather+oracle
                                (DESIGN.md §11; matches to fp32
                                round-off, so the Scheduler's
                                token-identity default stays the
                                oracle) (here + kernels/ops.py)
REPRO_OPT_SHARDKV      1        multi-device paged serving shards the
                                KV block pools over the mesh "data"
                                axis on kv_heads (DESIGN.md §13); 0 =
                                fully-replicated pools (the A/B
                                baseline — outputs identical, per-
                                device KV bytes ×data larger)
                                (parallel/sharding.paged_rules)
REPRO_OPT_SPARSESKIP   0        off-TPU row-granular N:M-sparse matmuls
                                lower to the compressed-skip reference
                                (gather kept activation columns,
                                contract only kept rows — the measured
                                speedup arm); 0 = the dense-mask
                                reconstruction, bit-identical to the
                                dense-masked checkpoint so serving
                                stays token-identical (DESIGN.md §14)
                                (kernels/ops.py)
REPRO_BASELINE         0        1 = force every REPRO_OPT_* flag off at
                                once (here)
REPRO_CHUNK_ORACLE     0        1 = pin every chunked-prefill/verify
                                attention to the PR 5 materialized
                                gather oracle on ALL backends — the
                                rollback switch and the BENCH_pr6
                                dense arm (kernels/ops.py)
REPRO_FORCE_PALLAS     unset    1 = run the Pallas kernel path in
                                interpret mode off-TPU; 0 = force the
                                oracle path on TPU (kernels/ops.py;
                                tests use ``ops.force_pallas``)
REPRO_BENCH_JSON       unset    output path override for the full
                                benchmark artifact, default
                                BENCH_pr3.json (benchmarks/run.py)
REPRO_BENCH_PR5_JSON   unset    path override for the paged-serving
                                row artifact (benchmarks/run.py)
REPRO_BENCH_PR6_JSON   unset    path override for the chunked-prefill
                                row artifact (benchmarks/run.py)
REPRO_BENCH_PR7_JSON   unset    path override for the speculative/beam
                                row artifact (benchmarks/run.py)
REPRO_BENCH_PR8_JSON   unset    path override for the multi-device
                                sharded-serving row artifact
                                (benchmarks/run.py)
REPRO_BENCH_PR9_JSON   unset    path override for the structured-
                                sparsity row artifact
                                (benchmarks/run.py)
REPRO_BENCH_PR10_JSON  unset    path override for the serving-telemetry
                                row artifact (benchmarks/run.py)
REPRO_TRACE            unset    1 = the process-default Tracer records
                                request-lifecycle spans (Chrome-trace
                                export, DESIGN.md §15); unset/0 = every
                                tracing call is a zero-cost no-op.
                                Explicit ``trace=`` arguments override
                                the default (obs/__init__.py)
REPRO_METRICS          unset    1 = the process-default Metrics
                                registry records counters/histograms
                                (Prometheus export, DESIGN.md §15);
                                unset/0 = shared null instruments.
                                Explicit ``metrics=`` arguments
                                override the default (obs/__init__.py)
=====================  =======  =========================================
"""
import os


def opt(name: str, default: bool = True) -> bool:
    if os.environ.get("REPRO_BASELINE") == "1":
        return False
    v = os.environ.get(f"REPRO_OPT_{name}")
    if v is None:
        return default
    return v == "1"
