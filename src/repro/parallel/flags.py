"""Optimization flags (EXPERIMENTS.md §Perf).

The hillclimbed optimizations are framework DEFAULTS; each can be
disabled for A/B against the paper-faithful baseline:

  REPRO_OPT_FLASH=0    materialized-score attention oracle (baseline)
  REPRO_OPT_SEQKV=0    head-dim-sharded KV cache (baseline decode layout)
  REPRO_OPT_EPMODEL=0  experts sharded over "data" (baseline MoE layout)
  REPRO_OPT_GRADRS=1   pin grads to the param sharding (measured no-op:
                       GSPMD already propagates it — §Perf, refuted)
  REPRO_BASELINE=1     all of the above at once
  REPRO_OPT_EPMOE=1    (refuted ablation) pin dispatched tokens E→"data"
"""
import os


def opt(name: str, default: bool = True) -> bool:
    if os.environ.get("REPRO_BASELINE") == "1":
        return False
    v = os.environ.get(f"REPRO_OPT_{name}")
    if v is None:
        return default
    return v == "1"
