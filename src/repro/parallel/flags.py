"""Optimization flags (EXPERIMENTS.md §Perf).

The hillclimbed optimizations are framework DEFAULTS; each can be
disabled for A/B against the paper-faithful baseline:

  REPRO_OPT_FLASH=0    materialized-score attention oracle (baseline)
  REPRO_OPT_SEQKV=0    head-dim-sharded KV cache (baseline decode layout)
  REPRO_OPT_EPMODEL=0  experts sharded over "data" (baseline MoE layout)
  REPRO_OPT_GRADRS=1   pin grads to the param sharding (measured no-op:
                       GSPMD already propagates it — §Perf, refuted)
  REPRO_BASELINE=1     all of the above at once
  REPRO_OPT_EPMOE=1    (refuted ablation) pin dispatched tokens E→"data"

Opt-IN flags (default off — they change off-TPU lowering choices):

  REPRO_OPT_PAGEDFLASH=1  off-TPU chunk-prefill attention lowers to the
                       O(written-prefix) online-softmax scan instead of
                       the bit-exact PR 5 gather+oracle (DESIGN.md §11;
                       matches to fp32 round-off, so the Scheduler's
                       token-identity default stays the oracle)

Related (read by kernels/ops.py, not here): REPRO_CHUNK_ORACLE=1 pins
every chunked-prefill attention to the PR 5 materialized gather oracle
on ALL backends — the rollback switch and the BENCH_pr6 dense arm.
"""
import os


def opt(name: str, default: bool = True) -> bool:
    if os.environ.get("REPRO_BASELINE") == "1":
        return False
    v = os.environ.get(f"REPRO_OPT_{name}")
    if v is None:
        return default
    return v == "1"
