"""Logical-axis sharding: map the model's logical axis names onto mesh
axes with divisibility-aware fallback.

Rules are *priority lists*: for each logical axis we try candidate mesh
axes in order, skipping candidates already used by another dim of the
same tensor (a mesh axis may appear at most once in a PartitionSpec) and
candidates that do not divide the dim (jit in_shardings rejects uneven
sharding, so e.g. whisper's 20 KV heads on a 16-way model axis fall
through to sharding head_dim instead).

Two rule sets:
  * TRAIN — FSDP-style: batch over (pod, data); TP over "model" on
    vocab/qkv/mlp/inner; params additionally sharded over "data" on the
    "embed" dim (and experts over "data") so 480B-class params +
    optimizer state fit the pod.
  * SERVE — weights sharded over "model" only (embed replicated) for
    latency; experts still over "data"; caches over batch (+ head dims
    over "model").
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import is_axes_leaf

Rules = Dict[str, Tuple[Tuple[str, ...], ...]]

TRAIN_RULES: Rules = {
    "batch": ((("pod", "data")), ("data",)),
    "vocab": (("model",),),
    "qkv": (("model",),),
    "kv": (("model",),),
    "mlp": (("model",),),
    "inner": (("model",), ("data",)),
    "heads": (("model",),),
    "experts": (("data",), ("model",)),
    "embed": (("data",),),            # FSDP
    # (experts→model variant selectable via REPRO_OPT_EPMODEL — §Perf)
    "kv_heads": (("model",),),
    "head_dim": (("model",),),
    "seq": (),
    "layers": (),
    "state": (),
}

SERVE_RULES: Rules = dict(TRAIN_RULES)
SERVE_RULES.update({
    "embed": (),                       # replicate: decode latency path
    "experts": (("data",), ("model",)),
})

# Flash-decoding layout (REPRO_OPT_SEQKV=1, EXPERIMENTS.md §Perf): the KV
# cache's SEQ dim is sharded over "model" instead of head_dim. Each TP
# rank attends over its sequence shard with a local online-softmax; only
# the tiny (B,H,1) max/denominator and (B,H,1,D) partial products cross
# the mesh — instead of all-reducing S-length partial-D score tensors.
DECODE_RULES: Rules = dict(SERVE_RULES)
DECODE_RULES.update({
    "seq": (("model",),),
    "kv_heads": (),
    "head_dim": (),
})


def decode_rules() -> Rules:
    from repro.parallel.flags import opt
    return DECODE_RULES if opt("SEQKV") else SERVE_RULES


# Paged serving layout (DESIGN.md §13): the K/V block pools
# (L, NB, BS, Hkv, D) are the only sharded tensors of the paged engine.
# Block ids stay GLOBAL — the "blocks" dim is never split, so every
# device holds its shard of every block and the host-side KVBlockPool
# bookkeeping (refcounts, COW, prefix hashes) is mesh-oblivious. Each
# block's *contents* shard over "data" on the kv_heads dim — a batch dim
# of attention, so no contraction ever crosses shards and multi-device
# serving stays bit-identical to single-device (the all-gather of the
# head-sharded attention output happens before wo via
# ``act_sharding.constrain_replicated``). Block tables and positions are
# tiny host-side metadata: replicated. head_dim and block_tokens are
# contraction dims of the attention einsums — splitting them would
# reassociate the fp32 reductions and break token identity, so they
# carry no candidates at all.
PAGED_SERVE_RULES: Rules = {
    "layers": (),
    "blocks": (),                 # global block ids — never sharded
    "block_tokens": (),           # contraction dim (p·v) — keep local
    "kv_heads": (("data",), ("model",)),
    "head_dim": (),               # contraction dim (q·k) — keep local
    "batch": (),                  # block-table slot dim: host-replicated
    "table": (),                  # block-table entries: host-replicated
}


def paged_rules() -> Rules:
    """PAGED_SERVE_RULES, or the fully-replicated baseline layout under
    ``REPRO_OPT_SHARDKV=0`` / ``REPRO_BASELINE=1`` (A/B switch: the
    multi-device engine then runs the pool replicated like PR 7)."""
    from repro.parallel.flags import opt
    if not opt("SHARDKV"):
        return {name: () for name in PAGED_SERVE_RULES}
    return PAGED_SERVE_RULES


def paged_cache_shardings(mesh: Mesh, cache_axes_tree, cache_shape_tree):
    """NamedSharding tree for a paged cache ({"k","v"} pools (+"bt")
    with ``models.api.paged_cache_axes`` logical names)."""
    return tree_shardings(mesh, cache_axes_tree, cache_shape_tree,
                          paged_rules())


def train_rules() -> Rules:
    """TRAIN_RULES, with the expert dim on "model" (the §Perf-winning EP
    layout; gradient all-reduces of expert weights shrink 2.6x and the
    dispatch lowers to true all-to-all). REPRO_OPT_EPMODEL=0 restores
    the baseline experts→"data" layout."""
    from repro.parallel.flags import opt
    if opt("EPMODEL"):
        r = dict(TRAIN_RULES)
        r["experts"] = (("model",),)
        return r
    return TRAIN_RULES


def _normalize(cand) -> Tuple[str, ...]:
    return (cand,) if isinstance(cand, str) else tuple(cand)


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh, rules: Rules) -> P:
    """PartitionSpec for one tensor given its logical axes and shape."""
    assert len(axes) == len(shape), (axes, shape)
    used = set()
    parts = []
    for name, size in zip(axes, shape):
        assigned = None
        for cand in rules.get(name, ()) if name else ():
            cand_t = _normalize(cand)
            if any(a in used for a in cand_t):
                continue
            if any(a not in mesh.shape for a in cand_t):
                continue
            total = math.prod(mesh.shape[a] for a in cand_t)
            if size % total == 0:
                assigned = cand_t
                break
        # NOTE: jit in_shardings rejects uneven (padded) sharding, so a
        # non-divisible dim falls through to the next logical axis (e.g.
        # kv_heads=8 on a 16-way model axis → head_dim carries the TP
        # sharding of the KV cache instead).
        if assigned is None:
            parts.append(None)
        else:
            used.update(assigned)
            parts.append(assigned[0] if len(assigned) == 1 else assigned)
    return P(*parts)


def tree_shardings(mesh: Mesh, axes_tree, shape_tree, rules: Rules):
    """NamedSharding pytree for (axes_tree, shape_tree) — shape_tree is a
    ShapeDtypeStruct tree (e.g. from jax.eval_shape)."""
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=is_axes_leaf)
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    assert len(flat_axes) == len(flat_shapes), \
        (len(flat_axes), len(flat_shapes))
    shardings = [NamedSharding(mesh, spec_for(a, s.shape, mesh, rules))
                 for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, shardings)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_specs(batch_tree, mesh: Mesh, rules: Rules):
    """Shardings for a data batch: leading dim is batch (or the (3,B,S)
    position tensors where dim 1 is batch)."""

    def one(x):
        nd = len(x.shape)
        b_axis = 1 if nd == 3 and x.shape[0] == 3 else 0   # (3,B,S) posns
        cand = None
        for c in rules["batch"]:
            c_t = _normalize(c)
            if all(a in mesh.shape for a in c_t) and \
                    x.shape[b_axis] % math.prod(mesh.shape[a] for a in c_t) == 0:
                cand = c_t
                break
        parts = [None] * nd
        if cand is not None:
            parts[b_axis] = cand[0] if len(cand) == 1 else cand
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh: Mesh, cache_axes_tree, cache_shape_tree,
                    rules: Rules):
    return tree_shardings(mesh, cache_axes_tree, cache_shape_tree, rules)
