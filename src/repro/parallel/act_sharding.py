"""Activation sharding constraints (Megatron-SP style).

Between transformer blocks the residual stream (B, S, d) is constrained
to batch-over-("pod","data") × seq-over-"model": the rematerialization
carry saved per layer is then 1/TP of the naive size (the difference
between fitting and not fitting HBM for the 72B train cell — see
EXPERIMENTS.md §Perf), and GSPMD derives the Megatron sequence-parallel
all-gather/reduce-scatter pattern around attention/MLP automatically.

Constraints are best-effort: outside a mesh context (CPU unit tests) they
no-op, and a dim that is too small to be worth sharding is left alone.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    from repro.compat import get_abstract_mesh
    m = get_abstract_mesh()
    return m if m is not None and m.shape else None


def constrain_residual(x: jax.Array) -> jax.Array:
    """x (B, S, d) → sharding constraint (batch→pod/data, seq→model)."""
    mesh = _ambient_mesh()
    if mesh is None or x.ndim != 3:
        return x
    axes = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    b_ok = batch_axes and x.shape[0] % _prod(axes, batch_axes) == 0
    model_ok = "model" in axes and x.shape[1] % axes["model"] == 0 \
        and x.shape[1] >= 8 * axes["model"]
    if not (b_ok or model_ok):
        return x
    spec = P(batch_axes if b_ok else None,
             "model" if model_ok else None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_replicated(x: jax.Array) -> jax.Array:
    """Pin ``x`` fully replicated under an ambient multi-device mesh.

    The paged serving layout (DESIGN.md §13) shards ONLY the KV block
    pools (kv_heads over "data"); attention over them is head-local, so
    its output comes back sharded on the head dim. This constraint
    all-gathers that output BEFORE the wo projection: the contraction
    then runs on fully-replicated operands on every device, in the same
    reduction order as the single-device engine — which is what keeps
    multi-device serving token-identical rather than merely close
    (a sharded contraction would psum partial dots in a different fp32
    association). No-op outside a mesh context or on a 1-device mesh."""
    mesh = _ambient_mesh()
    if mesh is None or _prod(dict(mesh.shape), tuple(mesh.shape)) == 1:
        return x
    return jax.lax.with_sharding_constraint(x, P(*([None] * x.ndim)))


def constrain_batch_only(x: jax.Array) -> jax.Array:
    mesh = _ambient_mesh()
    if mesh is None or x.ndim < 1:
        return x
    axes = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    if not batch_axes or x.shape[0] % _prod(axes, batch_axes) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, *([None] * (x.ndim - 1))))


def _prod(axes, names):
    out = 1
    for n in names:
        out *= axes[n]
    return out
