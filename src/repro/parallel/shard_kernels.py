"""shard_map adapters for the paged attention kernels (DESIGN.md §13).

GSPMD can partition the *reference* lowerings of ``ops.paged_attention_
decode`` / ``ops.paged_flash_prefill`` automatically (they are plain XLA
ops), but a ``pallas_call`` is an opaque primitive — under a mesh it
would be fully replicated, gathering the sharded KV pool onto every
device and erasing the §13 memory win. These wrappers run the kernels
under ``shard_map`` with the HEAD dims sharded on the mesh "model"
axis:

* q heads H and pool kv_heads Hkv are split contiguously, so with GQA
  group size G = H/Hkv every shard keeps whole query groups and the
  kernels' local h → h//G mapping is unchanged;
* block tables / lengths / start are replicated (block ids are global);
* per-(batch, head) programs are independent — no cross-device term
  exists in attention over distinct heads — so the sharded composition
  is BIT-identical to the unsharded kernel, not merely close.

The head dim is sharded on "model" (not "data") because the §13 paged
layout already spends "data" on the kv_heads dim of the *pool at rest*;
under ``shard_map`` both placements compose with the same specs. Use
``head_shard_axis`` to pick the widest eligible axis.

``ops`` routes through here when a multi-device mesh is ambient at
trace time and the head counts divide; ``_entered()`` guards the
re-entrant ``ops`` call inside the shard_map body.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

_tls = threading.local()


def _entered() -> bool:
    return getattr(_tls, "inside", False)


@contextlib.contextmanager
def _enter():
    prev = _entered()
    _tls.inside = True
    try:
        yield
    finally:
        _tls.inside = prev


def head_shard_axis(mesh, num_heads: int, num_kv_heads: int,
                    preferred=("model", "data")) -> Optional[str]:
    """The first mesh axis (size > 1) that divides BOTH head counts —
    contiguous splits then keep GQA groups whole per shard. None when no
    axis qualifies (caller should run the kernel unsharded)."""
    axes = dict(mesh.shape)
    for name in preferred:
        n = axes.get(name, 1)
        if n > 1 and num_kv_heads % n == 0 and num_heads % n == 0:
            return name
    return None


def route_mesh(num_heads: int, num_kv_heads: int):
    """(mesh, axis) when the ambient mesh wants the shard_map kernel
    path, else None. Never routes from inside a shard_map body."""
    if _entered():
        return None
    from repro.parallel.act_sharding import _ambient_mesh
    mesh = _ambient_mesh()
    if mesh is None:
        return None
    ax = head_shard_axis(mesh, num_heads, num_kv_heads)
    return (mesh, ax) if ax is not None else None


def sharded_paged_attention_decode(mesh, ax, q, k_pool, v_pool,
                                   block_tables, lengths, **kw):
    """q (B, H, D), pools (NB, BS, Hkv, D) → (B, H, D); heads on ``ax``."""
    from repro.kernels import ops

    def body(q_, k_, v_, bt_, ln_):
        with _enter():
            return ops.paged_attention_decode(q_, k_, v_, bt_, ln_, **kw)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, ax, None), P(None, None, ax, None),
                  P(None, None, ax, None), P(None, None), P(None)),
        out_specs=P(None, ax, None), check_rep=False)
    return fn(q, k_pool, v_pool, block_tables, lengths)


def sharded_paged_flash_prefill(mesh, ax, q, k_pool, v_pool,
                                block_tables, start, **kw):
    """q (B, H, C, D), pools (NB, BS, Hkv, D) → (B, H, C, D)."""
    from repro.kernels import ops

    def body(q_, k_, v_, bt_, st_):
        with _enter():
            return ops.paged_flash_prefill(q_, k_, v_, bt_, st_, **kw)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, ax, None, None), P(None, None, ax, None),
                  P(None, None, ax, None), P(None, None), P(None)),
        out_specs=P(None, ax, None, None), check_rep=False)
    return fn(q, k_pool, v_pool, block_tables, start)
