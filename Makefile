# Tier-1 workflows. PYTHONPATH is set per-target so `make test` works
# from a clean checkout with no venv activation.

PY ?= python

.PHONY: test bench bench-fast bench-prefill bench-spec bench-report

test:
	PYTHONPATH=src $(PY) -m pytest -x -q --durations=10

bench:
	PYTHONPATH=src $(PY) benchmarks/smoke.py

bench-fast:
	PYTHONPATH=src $(PY) benchmarks/smoke.py --fast

# PR 6 chunked-prefill rows only, written to the canonical BENCH_pr6.json
bench-prefill:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_prefill]); run.write_json(run.PR6_JSON)"

# PR 7 speculative/beam rows only, written to the canonical BENCH_pr7.json
bench-spec:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_spec]); run.write_json(run.PR7_JSON)"

# perf trajectory across all BENCH_pr*.json artifacts
bench-report:
	$(PY) benchmarks/compare.py
