# Tier-1 workflows. PYTHONPATH is set per-target so `make test` works
# from a clean checkout with no venv activation.

PY ?= python

.PHONY: test test-multidevice bench bench-fast bench-prefill bench-spec \
	bench-shard bench-sparse bench-obs bench-report

test:
	PYTHONPATH=src $(PY) -m pytest -x -q --durations=10

bench:
	PYTHONPATH=src $(PY) benchmarks/smoke.py

bench-fast:
	PYTHONPATH=src $(PY) benchmarks/smoke.py --fast

# PR 6 chunked-prefill rows only, written to the canonical BENCH_pr6.json
bench-prefill:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_prefill]); run.write_json(run.PR6_JSON)"

# PR 7 speculative/beam rows only, written to the canonical BENCH_pr7.json
bench-spec:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_spec]); run.write_json(run.PR7_JSON)"

# PR 8 multi-device sharded-serving rows only (8-device subprocess),
# written to the canonical BENCH_pr8.json
bench-shard:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_shard]); run.write_json(run.PR8_JSON)"

# PR 9 structured N:M sparsity rows only, written to the canonical
# BENCH_pr9.json
bench-sparse:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_sparse]); run.write_json(run.PR9_JSON)"

# PR 10 serving-telemetry rows only (overhead gate, export validity,
# drift report), written to the canonical BENCH_pr10.json
bench-obs:
	PYTHONPATH=src:benchmarks $(PY) -c "import run; \
	  run.run_benches([run.bench_obs]); run.write_json(run.PR10_JSON)"

# multi-device test leg: paged sharding + token-identity sweep on an
# 8-way host mesh (the paged suite re-runs under the same mesh)
test-multidevice:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
	  $(PY) -m pytest -x -q tests/test_multidevice.py tests/test_paged.py

# perf trajectory across all BENCH_pr*.json artifacts
bench-report:
	$(PY) benchmarks/compare.py
