# Tier-1 workflows. PYTHONPATH is set per-target so `make test` works
# from a clean checkout with no venv activation.

PY ?= python

.PHONY: test bench bench-fast

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) benchmarks/smoke.py

bench-fast:
	PYTHONPATH=src $(PY) benchmarks/smoke.py --fast
