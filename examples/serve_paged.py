"""Paged-KV serving demo (DESIGN.md §10): a skewed workload — short chat
turns and long documents behind one shared system prefix — through the
block-pool Scheduler vs the dense-slot ContinuousBatcher, checking
token-for-token agreement and reporting the KV-memory and weight-stream
amortization wins paging buys.

    PYTHONPATH=src python examples/serve_paged.py [--slots 4] [--new 12]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models import api
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.paged import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new", type=int, default=12)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=2, d_model=128, d_ff=256,
        num_heads=4, num_kv_heads=2)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    max_len = 256
    system = rng.integers(2, cfg.vocab_size, size=32).tolist()
    skew = [8, 120, 16, 180, 24, 8, 64, 150, 12, 40]
    reqs = [Request(rid=i,
                    prompt=system + rng.integers(
                        2, cfg.vocab_size, size=n).tolist(),
                    max_new=args.new)
            for i, n in enumerate(skew)]

    cb = ContinuousBatcher(cfg, params, slots=args.slots, max_len=max_len)
    for r in reqs:
        cb.submit(r)
    t0 = time.perf_counter()
    dense_out = cb.run()
    t_dense = time.perf_counter() - t0

    # half the dense block budget — prefix sharing + paging absorb it;
    # the paged run carries live telemetry (DESIGN.md §15)
    nbmax = max_len // args.block_size
    metrics = obs.Metrics(enabled=True)
    sch = Scheduler(cfg, params, slots=args.slots, max_len=max_len,
                    block_size=args.block_size, chunk=args.chunk,
                    num_blocks=args.slots * nbmax // 2 + 2,
                    metrics=metrics)
    for r in reqs:
        sch.submit(r)
    t0 = time.perf_counter()
    paged_out = sch.run()
    t_paged = time.perf_counter() - t0

    agree = all(dense_out[r.rid] == paged_out[r.rid] for r in reqs)
    toks = sum(len(v) for v in paged_out.values())
    amort = sch.stream_amortization_report()
    print(f"slots={args.slots} requests={len(reqs)} "
          f"prompts={min(skew)+32}..{max(skew)+32} tokens")
    print(f"dense : {toks/t_dense:8.1f} tok/s  (wall {t_dense:.2f}s, "
          f"kv blocks allocated {args.slots * nbmax})")
    print(f"paged : {toks/t_paged:8.1f} tok/s  (wall {t_paged:.2f}s, "
          f"peak kv blocks {sch.pool.peak_in_use}, "
          f"pool {sch.pool.num_blocks})")
    print(f"kv bytes: paged peak {sch.kv_bytes_peak():,} vs dense "
          f"{sch.kv_bytes_dense_equiv():,} "
          f"({sch.kv_bytes_peak()/sch.kv_bytes_dense_equiv():.0%})")
    print(f"weight-stream amortization: mean active "
          f"{amort['mean_active']:.2f} -> modeled "
          f"{amort['speedup_vs_b1']:.2f}x over batch-1 decode")
    print("token-for-token agreement dense vs paged:", agree)

    ttft = metrics.get("ttft_seconds")
    itl = metrics.get("inter_token_seconds")
    print(f"telemetry: ttft {ttft.mean*1e3:.1f}ms mean over {ttft.count} "
          f"requests, inter-token {itl.mean*1e3:.2f}ms, "
          f"prefix hit rate {sch.pool.prefix_hit_rate:.0%}, "
          f"emitted {metrics.counter('tokens_emitted_total').value:.0f} "
          f"tokens (paged count: {toks})")


if __name__ == "__main__":
    main()
