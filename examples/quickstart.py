"""Quickstart: the whole RCW-CIM pipeline in miniature, on CPU.

Trains a tiny llama-family model on the synthetic LM stream, deploys it
exactly the way the paper deploys Llama2-7B — INT4 weights through the
WS-OCS kernel path, INT8-friendly activations, FP16-style LUT group
softmax, fused group-RMSNorm — and generates from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.serve.engine import Engine, ServeConfig, quantize_params
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    cfg = get_config("llama2-7b", smoke=True).replace(dtype=jnp.float32)
    mesh = make_host_mesh()
    dc = DataConfig(seed=0, batch_size=8, seq_len=64,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=100, log_every=20)
    tr = Trainer(cfg, mesh, dc, tc, OptConfig(lr=3e-3, warmup_steps=10,
                                              total_steps=100))
    print(f"model: {cfg.name} (smoke), params on mesh {dict(mesh.shape)}")
    tr.run(on_metrics=lambda s, m: print(
        f"  step {s:4d}  loss {m['loss']:.3f}  lr {m['lr']:.2e}"))

    # --- deploy: the paper's serving configuration -------------------
    scfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True,
                       use_fusion=True, dataflow="ws_ocs", rcw=True)
    qparams = quantize_params(jax.device_get(tr.params), scfg)
    eng = Engine(scfg, qparams, max_len=96)
    prompt = np.array([[1, 17, 42, 7]], np.int32)
    out = eng.generate(prompt, ServeConfig(max_new_tokens=16))
    print("W4A8 WS-OCS generation:", out[0].tolist())


if __name__ == "__main__":
    main()
