"""Reproduce the paper's ablation story from the framework's own
components: Table I access counts → Fig 8 reductions → Fig 9 latency
chains → Table II summary — then go beyond the paper with what-if sweeps
(context length, DRAM bandwidth, CIM capacity).

    PYTHONPATH=src python examples/paper_ablations.py
"""
import dataclasses

from repro.core.dataflow import Dataflow
from repro.sim import perf_model as pm
from repro.sim.chip import RCWCIM


def main():
    print("=== Fig 8(a): external DRAM access, prefill 1024 tokens ===")
    r = pm.fig8a_dram_reduction()
    print(f"  WS     : {r['ws_bytes']/1e9:7.1f} GB")
    print(f"  WS-OCS : {r['ws_ocs_bytes']/1e9:7.1f} GB"
          f"   reduction {r['reduction']*100:.1f}% (paper {r['paper']*100}%)")

    print("=== Fig 8(b): internal CIM weight updates ===")
    r = pm.fig8b_update_reduction()
    print(f"  WS-OS  : {r['ws_os_updates']/1e9:7.1f} GB written")
    print(f"  WS-OCS : {r['ws_ocs_updates']/1e9:7.1f} GB"
          f"   reduction {r['reduction']*100:.1f}% (paper {r['paper']*100}%)")

    print("=== Fig 9(a): prefill latency ===")
    r = pm.fig9a_prefill_reduction()
    print(f"  baseline WS-OS (no RCW): {r['baseline_s']:.2f} s /1024 tok")
    print(f"  WS-OCS + RCW           : {r['ws_ocs_s']:.2f} s"
          f"  → {r['per_token_ms']:.2f} ms/token (paper 4.2)")
    print(f"  reduction {r['reduction']*100:.2f}% (paper 49.76%)")

    print("=== Fig 9(b): decode latency chain ===")
    r = pm.fig9b_decode_reductions()
    print(f"  baseline         : {r['baseline_ms']:7.2f} ms/token")
    print(f"  + RCW            : {r['rcw_ms']:7.2f} ms  "
          f"(−{r['rcw_reduction']*100:.2f}%, paper −21.59%)")
    print(f"  + NL fusion      : {r['final_ms']:7.2f} ms  "
          f"(−{r['fusion_reduction']*100:.2f}%, paper −69.17%)")
    print(f"  decode throughput: {r['tokens_per_s']:.2f} tok/s (paper 26.87)")

    print("=== Table II summary ===")
    for k, v in pm.table2_summary().items():
        print(f"  {k:28s} {v}")

    print("\n=== beyond the paper: context-length sensitivity (decode) ===")
    for ctx in (256, 1024, 4096, 16384):
        tps = pm.decode_tokens_per_s(ctx=ctx)
        print(f"  ctx {ctx:6d}: {tps:6.2f} tok/s")

    print("=== beyond the paper: DRAM bandwidth scaling (decode) ===")
    print("  (write bw fixed: the CIM WRITE port becomes the bottleneck —")
    print("   the paper's core motivation — vs. write bw co-scaled)")
    for mult in (1, 2, 4, 8):
        chip = dataclasses.replace(RCWCIM, dram_gbps=102.4 * mult)
        t_fixed = pm.decode_latency(rcw=True, fusion=True, chip=chip)
        t_scaled = pm.decode_latency(rcw=True, fusion=True, chip=chip,
                                     write_bw=102.4e9 * mult)
        print(f"  {mult}x DDR5 ({102.4*mult:6.0f} GB/s): "
              f"write-bound {1/t_fixed:6.2f} tok/s | "
              f"co-scaled {1/t_scaled:6.2f} tok/s")

    print("=== beyond the paper: all five dataflows, prefill latency ===")
    for df in Dataflow:
        t = pm.prefill_latency(df, rcw=(df == Dataflow.WS_OCS))
        print(f"  {df.value:7s}: {t:7.2f} s /1024 tokens")


if __name__ == "__main__":
    main()
