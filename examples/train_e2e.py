"""End-to-end training driver with checkpoint/restart fault tolerance.

Defaults to a small model for CPU; ``--preset 100m`` builds a ~100M-param
llama-family model (the task-spec e2e scale — expect a long run on CPU;
on a real pod this is `launch/train.py` with a production config).

    PYTHONPATH=src python examples/train_e2e.py --steps 200
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300
"""
import argparse

import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    # ~10M params: CPU-friendly demo
    "10m": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                d_ff=704, vocab_size=4096),
    # ~100M params: the task-spec e2e scale
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=2048, vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, **PRESETS[args.preset])
    n_params = (cfg.num_layers * (4 * cfg.d_model * cfg.d_model // 4
                + 2 * cfg.d_model * (cfg.q_dim + cfg.kv_dim)
                + 3 * cfg.d_model * cfg.d_ff)
                + 2 * cfg.vocab_size * cfg.d_model)
    print(f"preset={args.preset} (~{n_params/1e6:.0f}M params), "
          f"steps={args.steps}, ckpt={args.ckpt_dir}")

    mesh = make_host_mesh()
    dc = DataConfig(seed=0, batch_size=args.batch, seq_len=args.seq,
                    vocab_size=cfg.vocab_size)
    tc = TrainConfig(total_steps=args.steps, log_every=10,
                     ckpt_every=max(50, args.steps // 4),
                     ckpt_dir=args.ckpt_dir, grad_accum=args.accum)
    oc = OptConfig(lr=3e-4 if args.preset == "100m" else 1e-3,
                   warmup_steps=max(10, args.steps // 20),
                   total_steps=args.steps)
    tr = Trainer(cfg, mesh, dc, tc, oc)
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step} "
              f"(delete {args.ckpt_dir} for a fresh run)")
    tr.run(on_metrics=lambda s, m: print(
        f"  step {s:5d}  loss {m['loss']:.4f}  "
        f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}"))
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
