"""End-to-end serving driver (the paper is an inference accelerator, so
this is the canonical e2e example): batched requests through prefill +
decode with KV caches, comparing the bf16 baseline against the paper's
W4A8 + LUT-group-softmax deployment — agreement and throughput.

    PYTHONPATH=src python examples/serve_batched.py [--batch 8] [--new 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Engine, ServeConfig, quantize_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("llama2-7b", smoke=True).replace(
        dtype=jnp.float32, num_layers=4, d_model=256, d_ff=512,
        num_heads=8, num_kv_heads=4)
    rng = np.random.default_rng(0)
    params = api.init(jax.random.PRNGKey(0), cfg)

    prompts = rng.integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.new + 1
    sc = ServeConfig(max_new_tokens=args.new)

    # bf16/f32 baseline
    eng = Engine(cfg, params, max_len=max_len)
    t0 = time.perf_counter()
    out_fp = eng.generate(prompts, sc)
    t_fp = time.perf_counter() - t0

    # the paper's deployment: W4A8 + LUT softmax + fused norms + WS-OCS
    scfg = cfg.replace(quant_mode="w4a8", use_lut_softmax=True)
    qeng = Engine(scfg, quantize_params(params, scfg), max_len=max_len)
    t0 = time.perf_counter()
    out_q = qeng.generate(prompts, sc)
    t_q = time.perf_counter() - t0

    agree = float((out_fp[:, args.prompt_len:] ==
                   out_q[:, args.prompt_len:]).mean())
    toks = args.batch * args.new
    print(f"batch={args.batch} prompt={args.prompt_len} new={args.new}")
    print(f"fp32  : {toks/t_fp:8.1f} tok/s  (wall {t_fp:.2f}s, incl compile)")
    print(f"w4a8  : {toks/t_q:8.1f} tok/s  (wall {t_q:.2f}s, incl compile)")
    print(f"greedy-token agreement w4a8 vs fp32: {agree*100:.1f}%")
    print("sample fp32:", out_fp[0, args.prompt_len:args.prompt_len+10].tolist())
    print("sample w4a8:", out_q[0, args.prompt_len:args.prompt_len+10].tolist())


if __name__ == "__main__":
    main()
